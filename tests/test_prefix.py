"""Prefix caching with copy-on-write block tables.

Three layers of pinning:

* ``BlockPool`` refcount property tests — random alloc/share/release
  traces never double-free, never leak, and physical ``in_use`` always
  equals the number of DISTINCT live blocks while ``logical_in_use``
  counts references.
* ``PrefixIndex`` + rolling-hash contract — chained hashes identify
  whole prefixes, first-writer-wins registration, LRU eviction order.
* End-to-end token identity — on dense, MLA and sliding-window lanes,
  a scheduler with ``prefix_cache=True`` (which rides the chunked-
  prefill lane: matched blocks skip their chunks) must emit EXACTLY
  the token streams the non-sharing paged scheduler emits (f32 KV
  storage: chunked prefill is bitwise-identical to a full prefill)
  while prefilling strictly fewer tokens on a shared-prefix trace.
  COW divergence after the shared prefix must never leak one request's
  tokens into another's blocks.
"""
import random

import numpy as np
import pytest

from repro.compress import kvcache as kvc
from repro.models import get_family
from repro.runtime.engine import Engine
from repro.runtime.scheduler import Scheduler

from test_paged import _cfg, _params

LANES = ["dense", "mla", "window"]


# ---------------------------------------------------------------------------
# BlockPool refcounting (property-style, stdlib random)
# ---------------------------------------------------------------------------

def test_block_pool_refcount_random_traces():
    """Random alloc/share/release traces: physical in_use == number of
    unique referenced blocks, logical_in_use == sum of refcounts,
    conservation holds, releases reclaim exactly at refcount zero, and
    double frees raise."""
    rng = random.Random(99)
    for _ in range(40):
        n_blocks = rng.randint(1, 48)
        pool = kvc.BlockPool(n_blocks)
        refs: dict = {}                 # block id -> live refcount
        for _ in range(300):
            assert pool.n_free + pool.in_use == n_blocks
            assert pool.in_use == len(refs)
            assert pool.logical_in_use == sum(refs.values())
            for b, r in refs.items():
                assert pool.refcount(b) == r
            op = rng.random()
            if op < 0.35 and pool.n_free:
                n = rng.randint(1, pool.n_free)
                for b in pool.alloc(n):
                    assert b not in refs          # never double-handed
                    refs[b] = 1
            elif op < 0.6 and refs:
                b = rng.choice(list(refs))
                pool.share([b])
                refs[b] += 1
            elif refs:
                b = rng.choice(list(refs))
                pool.release([b])
                refs[b] -= 1
                if refs[b] == 0:
                    del refs[b]
                    # now physically free: another release must raise
                    with pytest.raises(ValueError):
                        pool.free([b])
        assert pool.peak_in_use <= n_blocks
        assert pool.peak_logical >= pool.peak_in_use


def test_block_pool_share_requires_residency():
    pool = kvc.BlockPool(4)
    with pytest.raises(ValueError):
        pool.share([0])                 # not allocated yet
    (b,) = pool.alloc(1)
    pool.share([b])
    pool.release([b])
    assert pool.in_use == 1             # still held once
    pool.free([b])
    assert pool.in_use == 0 and pool.n_free == 4


def test_block_pool_alloc_skips_shared_blocks():
    """A block stays out of the free list while ANY reference lives."""
    pool = kvc.BlockPool(3)
    ids = pool.alloc(3)
    pool.share([ids[0]])
    pool.free(ids)                      # ids[0] survives via the share
    assert pool.n_free == 2
    assert set(pool.alloc(2)).isdisjoint({ids[0]})


# ---------------------------------------------------------------------------
# rolling hashes + PrefixIndex
# ---------------------------------------------------------------------------

def test_prefix_hashes_chain_full_blocks_only():
    toks = list(range(10))
    hs = kvc.prefix_block_hashes(toks, 4)
    assert len(hs) == 2                 # 10 // 4, trailing partial unhashed
    # hash i commits to the WHOLE prefix, not just block i
    other = [99] + toks[1:]
    hs2 = kvc.prefix_block_hashes(other, 4)
    assert hs2[0] != hs[0] and hs2[1] != hs[1]
    # agreement up to block 0 only
    mixed = toks[:4] + [7, 7, 7, 7, 7, 7]
    hs3 = kvc.prefix_block_hashes(mixed, 4)
    assert hs3[0] == hs[0] and hs3[1] != hs[1]


def test_prefix_index_lru_and_first_writer_wins():
    idx = kvc.PrefixIndex()
    assert idx.put("a", 1) and idx.put("b", 2)
    assert not idx.put("a", 3)          # first writer wins
    with pytest.raises(ValueError):
        idx.put("c", 1)                 # one hash per block
    assert idx.get("a") == 1            # bumps "a" to MRU
    assert idx.blocks_lru() == [2, 1]
    assert idx.pop_block(2) == "b"
    assert idx.get("b") is None
    assert len(idx) == 1


# ---------------------------------------------------------------------------
# end-to-end: sharing must be invisible to the tokens
# ---------------------------------------------------------------------------

def _run_trace(cfg, params, prompts, *, prefix_cache, max_new, bs, nb,
               max_len, n_slots=2, chunk=4, sanitize=True, warm=0):
    # sanitize=True by default: every prefix/paged trace in this suite
    # runs under the arena sanitizer (pre-chunk check_read/check_write
    # gates, poisoned reclaims, leak accounting at retirement) — it must
    # never change a token and must end leak-free.  ``warm``: requests
    # run to completion BEFORE the rest are submitted — prefix blocks
    # register when a prompt finishes its chunks, so a warm donor makes
    # every later admission matchable (concurrently-prefilling rows
    # cannot share with each other).
    eng = Engine(cfg, params, max_len=max_len, paged=True,
                 block_size=bs, n_blocks=nb, sanitize=sanitize)
    sched = Scheduler(eng, n_slots=n_slots, chunk_size=chunk,
                      prefix_cache=prefix_cache)
    done = {}
    rids = [sched.submit(p, max_new) for p in prompts[:warm]]
    done.update(sched.run(max_rounds=500))
    rids += [sched.submit(p, max_new) for p in prompts[warm:]]
    done.update(sched.run(max_rounds=500))
    toks = {r: done[r].tokens.tolist() for r in rids}
    if sanitize:
        assert sched.n_leaked == 0 and not sched.leak_report()
    return toks, sched


def _lane_trace(lane, rng):
    """Shared-prefix trace sized to each lane's sharing regime (window
    sharing needs the whole prompt inside the window)."""
    if lane == "window":
        shared = [int(t) for t in rng.integers(0, 200, 6)]
        prompts = [shared + [int(t) for t in rng.integers(0, 200, 2)]
                   for _ in range(4)]
        return prompts, dict(max_new=10, bs=2, nb=64, max_len=64)
    shared = [int(t) for t in rng.integers(0, 200, 40)]
    prompts = [shared + [int(t) for t in rng.integers(0, 200, 6)]
               for _ in range(4)]
    return prompts, dict(max_new=12, bs=8, nb=128, max_len=96)


@pytest.mark.slow
@pytest.mark.parametrize("lane", LANES)
def test_prefix_sharing_token_identical(lane):
    """COW divergence: requests borrowing a shared prefix emit exactly
    the tokens the non-sharing paged scheduler emits, on every lane."""
    cfg = _cfg(lane)
    params = _params(cfg)
    prompts, kw = _lane_trace(lane, np.random.default_rng(3))
    base, sb = _run_trace(cfg, params, prompts, prefix_cache=False,
                          warm=1, **kw)
    shared, ss = _run_trace(cfg, params, prompts, prefix_cache=True,
                            warm=1, **kw)
    assert shared == base
    assert ss.prefix_hits >= len(prompts) - 1
    assert ss.prefill_tokens < sb.prefill_tokens
    if lane != "window":
        # dense lanes: dedup must show up as PHYSICAL savings (window
        # trades memory for prefill work: ring COW pre-reserves copies)
        assert ss.peak_committed < sb.peak_committed
    assert ss.peak_logical >= ss.peak_committed


@pytest.mark.slow
def test_exact_duplicate_prompts_trigger_admission_cow():
    """A block-aligned full-prompt match still recomputes its last
    token (its logits seed tok0); that KV write lands in a COW copy of
    the boundary block, never the shared block itself."""
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(5)
    p0 = [int(t) for t in rng.integers(0, 200, 24)]       # 24 % 4 == 0
    prompts = [p0, list(p0), list(p0)]
    kw = dict(max_new=8, bs=4, nb=64, max_len=64)
    base, _ = _run_trace(cfg, params, prompts, prefix_cache=False,
                         warm=1, **kw)
    shared, ss = _run_trace(cfg, params, prompts, prefix_cache=True,
                            warm=1, **kw)
    assert shared == base
    assert ss.n_cow >= 2                # one COW per duplicate admission


@pytest.mark.slow
def test_prefix_eviction_under_pressure_token_identical():
    """Distinct prefix families on a tight pool: admissions evict
    index-only blocks LRU-first, streams stay identical, and the
    drained pool holds exactly the index's references."""
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(11)
    fams = [[int(t) for t in rng.integers(0, 200, 24)] for _ in range(3)]
    prompts = [fams[i % 3] + [int(t) for t in rng.integers(0, 200, 5)]
               for i in range(9)]
    kw = dict(max_new=8, bs=4, nb=24, max_len=64)
    base, _ = _run_trace(cfg, params, prompts, prefix_cache=False, **kw)
    shared, ss = _run_trace(cfg, params, prompts, prefix_cache=True, **kw)
    assert shared == base
    assert ss.n_evicted > 0
    assert ss.pool.in_use == len(ss.index)
    for b in ss.index.blocks_lru():
        assert ss.pool.refcount(b) == 1


@pytest.mark.slow
def test_window_ring_recycling_cows_shared_blocks():
    """Window lane: decode recycles ring slots holding shared blocks;
    the pre-chunk COW pass must duplicate them first (streams identical,
    COWs actually fire)."""
    cfg = _cfg("window")
    params = _params(cfg)
    prompts, kw = _lane_trace("window", np.random.default_rng(3))
    base, _ = _run_trace(cfg, params, prompts, prefix_cache=False,
                         warm=1, **kw)
    shared, ss = _run_trace(cfg, params, prompts, prefix_cache=True,
                            warm=1, **kw)
    assert shared == base
    assert ss.n_cow > 0


@pytest.mark.slow
def test_sanitizer_catches_skipped_window_cow(monkeypatch):
    """Seeded COW-skip, end to end: with the pre-chunk ring COW pass
    disabled, the window lane's decode chunk would write through a
    shared (refcount > 1) block — the sanitizer's ``check_write`` gate
    must abort with a COW violation BEFORE the device write corrupts
    the donor's KV."""
    cfg = _cfg("window")
    params = _params(cfg)
    prompts, kw = _lane_trace("window", np.random.default_rng(3))
    monkeypatch.setattr(Scheduler, "_cow_window_rows",
                        lambda self: False)
    with pytest.raises(kvc.BlockSanitizerError, match="COW violation"):
        _run_trace(cfg, params, prompts, prefix_cache=True, warm=1, **kw)


def test_prefix_cache_requires_paged_engine():
    cfg = _cfg("dense")
    params = _params(cfg)
    eng = Engine(cfg, params, max_len=32)
    with pytest.raises(ValueError, match="paged"):
        Scheduler(eng, n_slots=1, prefix_cache=True)
