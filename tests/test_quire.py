"""Exact 512-bit quire (Posit Standard 2022) — beyond-paper vpdot mode."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import f32_to_posit, vpdot
from repro.core import softposit_ref as ref
from repro.core.types import POSIT16, POSIT32


@pytest.mark.slow          # 40x16 exact-Fraction quire cross-check
def test_quire_matches_golden_random():
    rng = np.random.default_rng(21)
    rows, length = 40, 16
    a = rng.integers(0, 2 ** 32, size=(rows, length), dtype=np.uint32)
    b = rng.integers(0, 2 ** 32, size=(rows, length), dtype=np.uint32)
    got = np.asarray(vpdot(jnp.asarray(a), jnp.asarray(b), POSIT32,
                           mode="quire")).astype(np.uint32)
    want = np.array([ref.dot(a[i], b[i], POSIT32) for i in range(rows)],
                    dtype=np.uint32)
    assert (got == want).all()


def test_quire_exact_under_catastrophic_cancellation():
    """Exponent spread of 160 bits: beyond the 128-bit quire-lite window
    but exact in the 512-bit standard quire."""
    big, tiny = float(2.0 ** 40), float(2.0 ** -40)
    a = np.asarray(f32_to_posit(
        jnp.asarray([[big, -big, tiny]], jnp.float32), POSIT32))
    b = np.asarray(f32_to_posit(
        jnp.asarray([[big, big, tiny]], jnp.float32), POSIT32))
    want = ref.dot(a[0], b[0], POSIT32)

    lite = int(np.asarray(vpdot(jnp.asarray(a), jnp.asarray(b), POSIT32,
                                mode="quire_lite"))[0])
    exact = int(np.asarray(vpdot(jnp.asarray(a), jnp.asarray(b), POSIT32,
                                 mode="quire"))[0])
    assert exact == want                      # 2^80 - 2^80 + 2^-80 exact
    assert lite != want                       # documents the lite limit


def test_quire_posit16():
    rng = np.random.default_rng(22)
    rows, length = 30, 8
    a = rng.integers(0, 2 ** 16, size=(rows, length),
                     dtype=np.uint32)
    b = rng.integers(0, 2 ** 16, size=(rows, length),
                     dtype=np.uint32)
    got = np.asarray(vpdot(jnp.asarray(a), jnp.asarray(b), POSIT16,
                           mode="quire")).astype(np.uint32)
    want = np.array([ref.dot(a[i], b[i], POSIT16) for i in range(rows)],
                    dtype=np.uint32)
    assert (got == want).all()


def test_quire_streams_beyond_tile_cap():
    """mode='quire' reductions longer than MAX_DOT_LENGTH stream tiles
    through exact 512-bit adds; a sum engineered to cancel down to a
    tiny cross-tile residual comes out exact."""
    n = 8192
    vals = np.zeros((1, n), np.float32)
    vals[0, 0] = 2.0 ** 40          # big term in tile 0 ...
    vals[0, -1] = -(2.0 ** 40)      # ... cancelled from tile 1
    vals[0, 1] = 2.0 ** -40         # leaves exactly 2^-80 after squaring
    a = f32_to_posit(jnp.asarray(vals), POSIT32)
    ones = f32_to_posit(jnp.asarray(np.where(vals < 0, -vals, vals)
                                    .astype(np.float32)), POSIT32)
    # a . ones = 2^80 - 2^80 + 2^-80
    got = int(np.asarray(vpdot(a, ones, POSIT32, mode="quire"))[0])
    want = ref.from_float(float(2.0 ** -80), POSIT32)
    assert got == want


def test_quire_zero_and_nar():
    cfg = POSIT32
    one = np.uint32(ref.from_float(1.0, cfg))
    nar = np.uint32(cfg.nar_pattern)
    a = jnp.asarray([[one, one], [one, nar], [0, 0]], jnp.uint32)
    b = jnp.asarray([[one, (-int(one)) & cfg.mask], [one, one], [0, 0]],
                    jnp.uint32)
    out = np.asarray(vpdot(a, b, cfg, mode="quire")).astype(np.uint32)
    assert out[0] == 0                        # 1 - 1 = 0 exactly
    assert out[1] == cfg.nar_pattern          # NaR propagates
    assert out[2] == 0
