"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, bit-exact."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import softposit_ref as golden
from repro.core.types import POSIT8, POSIT16, POSIT32, PositConfig
from repro.kernels import ops, ref

# Long interpret-mode sweeps (big tiles, wide configs) run on the full
# lane only; the fast PR lane (-m "not slow") keeps one representative
# per axis.  See pyproject.toml [tool.pytest.ini_options].
_slow = pytest.mark.slow

CODEC_CFGS = [POSIT8, POSIT16, POSIT32, PositConfig(16, 1)]
SHAPES_2D = [(8, 128), pytest.param((256, 512), marks=_slow),
             pytest.param((100, 130), marks=_slow), (1, 1), (3, 7)]

EW_OPS = {"add": ops.vadd, "sub": ops.vsub, "mul": ops.vmul,
          "div": lambda a, b, cfg: ops.vdiv(a, b, cfg, mode="exact")}
EW_GOLDEN = {"add": golden.add, "sub": golden.sub, "mul": golden.mul,
             "div": golden.div}


def _rand_f32(rng, shape):
    return (rng.standard_normal(shape) *
            np.exp(rng.uniform(-10, 10, shape))).astype(np.float32)


@pytest.mark.parametrize("cfg", CODEC_CFGS, ids=lambda c: c.name)
@pytest.mark.parametrize("shape", SHAPES_2D)
def test_codec_quantize_matches_ref(cfg, shape):
    rng = np.random.default_rng(hash((cfg.nbits, shape)) % 2 ** 31)
    x = jnp.asarray(_rand_f32(rng, shape))
    got = np.asarray(ops.quantize(x, cfg))
    want = np.asarray(ref.quantize_2d_ref(x, cfg))
    assert got.dtype == want.dtype
    assert (got == want).all()


@pytest.mark.parametrize("cfg", CODEC_CFGS, ids=lambda c: c.name)
@pytest.mark.parametrize("shape", [(16, 256), (33, 5)])
def test_codec_dequantize_matches_ref(cfg, shape):
    rng = np.random.default_rng(1)
    pats = rng.integers(0, 2 ** cfg.nbits, size=shape, dtype=np.uint64)
    p = jnp.asarray(pats.astype(np.uint32)).astype(cfg.storage_dtype)
    got = np.asarray(ops.dequantize(p, cfg))
    want = np.asarray(ref.dequantize_2d_ref(p, cfg))
    both_nan = np.isnan(got) & np.isnan(want)
    assert ((got == want) | both_nan).all()


def test_codec_roundtrip_high_rank():
    cfg = POSIT16
    rng = np.random.default_rng(2)
    x = jnp.asarray(_rand_f32(rng, (3, 5, 64)))
    p = ops.quantize(x, cfg)
    assert p.shape == x.shape
    back = ops.dequantize(p, cfg)
    # every posit16 value is f32-exact, so roundtrip == direct quantization
    want = np.asarray(ref.dequantize_2d_ref(ref.quantize_2d_ref(x, cfg), cfg))
    assert (np.asarray(back) == want).all()


@pytest.mark.parametrize("cfg", [POSIT16, POSIT8], ids=lambda c: c.name)
@pytest.mark.parametrize("mkn", [(16, 32, 8),
                                 pytest.param((128, 256, 128), marks=_slow),
                                 (33, 65, 17),
                                 pytest.param((256, 128, 512), marks=_slow)])
def test_posit_gemm_matches_ref(cfg, mkn):
    m, k, n = mkn
    rng = np.random.default_rng(hash((cfg.nbits, mkn)) % 2 ** 31)
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = rng.integers(0, 2 ** cfg.nbits, size=(k, n), dtype=np.uint64)
    # avoid NaR weights (a real checkpoint never contains NaR)
    w[w == cfg.nar_pattern] = 0
    wp = jnp.asarray(w.astype(np.uint32)).astype(cfg.storage_dtype)
    got = np.asarray(ops.gemm(a, wp, cfg))
    want = np.asarray(ref.posit_gemm_ref(a, wp, cfg))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Fused elementwise kernels (posit_ew)
# ---------------------------------------------------------------------------

def _edge_patterns(cfg):
    """Zero, NaR, maxpos, minpos and their negations — the encode/decode
    edge cases every elementwise op must propagate correctly."""
    return np.array([0, cfg.nar_pattern, cfg.maxpos_pattern, 1,
                     (-1) & cfg.mask,
                     (-cfg.maxpos_pattern) & cfg.mask], np.uint32)


def _rand_patterns(cfg, n, seed):
    rng = np.random.default_rng(seed)
    pats = rng.integers(0, 2 ** cfg.nbits, size=n, dtype=np.uint64)
    return np.concatenate([_edge_patterns(cfg),
                           pats.astype(np.uint32)])


@pytest.mark.parametrize("cfg", [POSIT8, POSIT16,
                                 pytest.param(POSIT32, marks=_slow),
                                 pytest.param(PositConfig(16, 1),
                                              marks=_slow)],
                         ids=lambda c: c.name)
@pytest.mark.parametrize("op", sorted(EW_OPS))
def test_elementwise_kernel_matches_golden(cfg, op):
    """Golden-value check: fused kernel == SoftPosit-semantics golden for
    add/sub/mul/div(exact), including NaR/zero/sign edge cases."""
    a = _rand_patterns(cfg, 200, seed=hash((cfg.nbits, cfg.es, op)) % 2**31)
    b = _rand_patterns(cfg, 200, seed=hash((op, cfg.es, cfg.nbits)) % 2**31)
    # cross every edge pattern with every other edge pattern too
    edges = _edge_patterns(cfg)
    ea = np.repeat(edges, edges.size)
    eb = np.tile(edges, edges.size)
    a, b = np.concatenate([a, ea]), np.concatenate([b, eb])
    ja = jnp.asarray(a).astype(cfg.storage_dtype)
    jb = jnp.asarray(b).astype(cfg.storage_dtype)
    got = np.asarray(EW_OPS[op](ja, jb, cfg)).astype(np.uint32)
    want = np.array([EW_GOLDEN[op](int(x), int(y), cfg)
                     for x, y in zip(a, b)], np.uint32)
    bad = np.nonzero(got != want)[0]
    assert bad.size == 0, (
        f"{op} {cfg.name}: {bad.size} mismatches; first at "
        f"a={a[bad[0]]:#x} b={b[bad[0]]:#x} got={got[bad[0]]:#x} "
        f"want={want[bad[0]]:#x}")


@pytest.mark.parametrize("cfg", [POSIT8, POSIT16], ids=lambda c: c.name)
@pytest.mark.parametrize("op", ["add", "mul"])
def test_elementwise_fused_bit_identical_to_roundtrip(cfg, op):
    """Acceptance criterion: fused vadd/vmul == dequantize -> f32 op ->
    quantize, bit for bit, on posit8e2 and posit16e2.

    Both paths are exactly rounded here: the fused kernel by construction
    (single RNE from the exact PIR result), the round-trip because the
    double rounding is innocuous at these widths — a posit16e2
    significand has <= 12 bits, so products (<= 24 bits) are f32-exact,
    and for sums the f32 ulp sits so far below the posit rounding
    position that the second rounding cannot cross a posit midpoint."""
    if cfg.nbits == 8:
        pats = np.arange(256, dtype=np.uint32)          # exhaustive
        a = np.repeat(pats, 256)
        b = np.tile(pats, 256)
    else:
        rng = np.random.default_rng(7)
        a = rng.integers(0, 2 ** 16, 200_000, dtype=np.uint64)
        a = a.astype(np.uint32)
        b = rng.integers(0, 2 ** 16, 200_000, dtype=np.uint64)
        b = b.astype(np.uint32)
    ja = jnp.asarray(a).astype(cfg.storage_dtype)
    jb = jnp.asarray(b).astype(cfg.storage_dtype)
    got = np.asarray(EW_OPS[op](ja, jb, cfg))
    want = np.asarray(ref.elementwise_roundtrip_ref(ja, jb, cfg, op))
    bad = np.nonzero(got != want)[0]
    assert bad.size == 0, (
        f"{op} {cfg.name}: {bad.size} fused/round-trip mismatches; first "
        f"a={a[bad[0]]:#x} b={b[bad[0]]:#x}")


@pytest.mark.parametrize("cfg", [POSIT16,
                                 pytest.param(POSIT32, marks=_slow)],
                         ids=lambda c: c.name)
@pytest.mark.parametrize("op", sorted(EW_OPS))
@pytest.mark.parametrize("shape", [(1, 1), (3, 7), (100, 130),
                                   pytest.param((256, 512), marks=_slow)])
def test_elementwise_kernel_matches_jnp_datapath(cfg, op, shape):
    """The Pallas kernel must be bit-identical to the pure-jnp PIR
    datapath (core.posit.vp*) across block/pad boundaries."""
    rng = np.random.default_rng(hash((cfg.nbits, op, shape)) % 2 ** 31)
    a = rng.integers(0, 2 ** cfg.nbits, size=shape, dtype=np.uint64)
    b = rng.integers(0, 2 ** cfg.nbits, size=shape, dtype=np.uint64)
    ja = jnp.asarray(a.astype(np.uint32)).astype(cfg.storage_dtype)
    jb = jnp.asarray(b.astype(np.uint32)).astype(cfg.storage_dtype)
    got = np.asarray(EW_OPS[op](ja, jb, cfg))
    dm = "exact" if op == "div" else "nr3"
    want = np.asarray(ref.elementwise_ref(ja, jb, cfg, op, div_mode=dm))
    assert got.dtype == want.dtype
    assert (got == want).all()


def test_elementwise_nr3_divider_in_kernel():
    """The paper-faithful NR-3 divider runs inside the kernel too and
    matches the jnp datapath bit for bit (including its residual error)."""
    cfg = POSIT32
    rng = np.random.default_rng(3)
    a = rng.integers(0, 2 ** 32, 4096, dtype=np.uint64).astype(np.uint32)
    b = rng.integers(0, 2 ** 32, 4096, dtype=np.uint64).astype(np.uint32)
    ja, jb = jnp.asarray(a), jnp.asarray(b)
    got = np.asarray(ops.vdiv(ja, jb, cfg, mode="nr3"))
    want = np.asarray(ref.elementwise_ref(ja, jb, cfg, "div",
                                          div_mode="nr3"))
    assert (got == want).all()


def test_elementwise_scalar_broadcast():
    """Scalar (and degenerate-axis) operands broadcast like jnp."""
    cfg = POSIT16
    rng = np.random.default_rng(5)
    a = rng.integers(0, 2 ** 16, size=(6, 40), dtype=np.uint64)
    ja = jnp.asarray(a.astype(np.uint32)).astype(cfg.storage_dtype)
    half = jnp.asarray(golden.from_float(0.5, cfg), cfg.storage_dtype)
    got = np.asarray(ops.vmul(ja, half, cfg))
    assert got.shape == (6, 40)
    want = np.asarray(ref.elementwise_ref(
        ja, jnp.broadcast_to(half, ja.shape), cfg, "mul"))
    assert (got == want).all()
    # row vector against matrix
    row = ja[:1]
    got2 = np.asarray(ops.vadd(ja, row, cfg))
    want2 = np.asarray(ref.elementwise_ref(
        ja, jnp.broadcast_to(row, ja.shape), cfg, "add"))
    assert got2.shape == (6, 40) and (got2 == want2).all()


@pytest.mark.parametrize("cfg", [pytest.param(POSIT32, marks=_slow),
                                 POSIT16], ids=lambda c: c.name)
@pytest.mark.parametrize("rl", [(4, 16), (128, 64), (57, 33)])
def test_vpdot_kernel_bit_exact(cfg, rl):
    rows, length = rl
    rng = np.random.default_rng(hash((cfg.nbits, rl)) % 2 ** 31)
    a = rng.integers(0, 2 ** cfg.nbits, size=(rows, length),
                     dtype=np.uint64).astype(np.uint32)
    b = rng.integers(0, 2 ** cfg.nbits, size=(rows, length),
                     dtype=np.uint64).astype(np.uint32)
    ja = jnp.asarray(a).astype(cfg.storage_dtype)
    jb = jnp.asarray(b).astype(cfg.storage_dtype)
    got = np.asarray(ops.dot_rows(ja, jb, cfg))
    want = np.asarray(ref.vpdot_rows_ref(ja, jb, cfg))
    assert (got == want).all()


def _rand_rows(cfg, shape, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2 ** cfg.nbits, size=shape,
                        dtype=np.uint64).astype(np.uint32)


def _pat(cfg, a):
    return jnp.asarray(a).astype(cfg.storage_dtype)


def test_dot_rows_rank1_regression():
    """ops.dot_rows used to crash on rank-1 inputs (`ValueError: not
    enough values to unpack`); it must behave like every other ops
    wrapper: a vector dot returns a scalar pattern."""
    cfg = POSIT16
    a = _rand_rows(cfg, (96,), 11)
    b = _rand_rows(cfg, (96,), 12)
    got = ops.dot_rows(_pat(cfg, a), _pat(cfg, b), cfg)
    assert got.shape == ()
    want = np.asarray(ref.vpdot_rows_ref(_pat(cfg, a[None]),
                                         _pat(cfg, b[None]), cfg))[0]
    assert np.asarray(got) == want


def test_dot_rows_batched_and_broadcast():
    """Leading batch dims flatten/restore; operands broadcast like jnp
    (a single vector against a batched stack)."""
    cfg = POSIT16
    a = _rand_rows(cfg, (2, 3, 40), 13)
    b = _rand_rows(cfg, (2, 3, 40), 14)
    got = np.asarray(ops.dot(_pat(cfg, a), _pat(cfg, b), cfg))
    assert got.shape == (2, 3)
    want = np.asarray(ref.vpdot_rows_ref(
        _pat(cfg, a.reshape(6, 40)), _pat(cfg, b.reshape(6, 40)),
        cfg)).reshape(2, 3)
    assert (got == want).all()
    vec = b[0, 0]
    got_b = np.asarray(ops.dot(_pat(cfg, a), _pat(cfg, vec), cfg))
    want_b = np.asarray(ref.vpdot_rows_ref(
        _pat(cfg, a.reshape(6, 40)),
        _pat(cfg, np.broadcast_to(vec, (6, 40))), cfg)).reshape(2, 3)
    assert (got_b == want_b).all()


def test_dot_rows_beyond_old_cap_matches_quire():
    """Reductions past the old MAX_DOT_LENGTH=4096 cap (which died with a
    bare AssertionError) now stream through K tiles — and on
    bounded-spread data the result equals the exact 512-bit standard
    quire bit for bit."""
    cfg = POSIT16
    rng = np.random.default_rng(15)
    length = 8192
    x = (rng.uniform(1.0, 2.0, (3, length)) *
         rng.choice([-1.0, 1.0], (3, length))).astype(np.float32)
    y = (rng.uniform(1.0, 2.0, (3, length)) *
         rng.choice([-1.0, 1.0], (3, length))).astype(np.float32)
    from repro.core import f32_to_posit
    ja = f32_to_posit(jnp.asarray(x), cfg)
    jb = f32_to_posit(jnp.asarray(y), cfg)
    got = np.asarray(ops.dot_rows(ja, jb, cfg))
    assert (got == np.asarray(ref.vpdot_rows_ref(ja, jb, cfg))).all()
    assert (got == np.asarray(ref.vpdot_quire_ref(ja, jb, cfg))).all()


def test_dot_rows_long_random_patterns_match_streaming_ref():
    """Arbitrary random patterns (full exponent range, NaR excluded) at a
    non-multiple length: tiled kernel == the chunked core reference."""
    cfg = POSIT32
    a = _rand_rows(cfg, (2, 5000), 16)
    b = _rand_rows(cfg, (2, 5000), 17)
    got = np.asarray(ops.dot_rows(_pat(cfg, a), _pat(cfg, b), cfg))
    want = np.asarray(ref.vpdot_rows_ref(_pat(cfg, a), _pat(cfg, b), cfg))
    assert (got == want).all()


def test_dot_rows_edge_cases_across_tiles():
    """Zero rows, and NaR appearing only in a *later* K tile, survive the
    cross-tile quire state (forced multi-tile via block_k=64)."""
    from repro.kernels import posit_dot
    cfg = POSIT16
    length = 200                      # 4 tiles of 64 (padded)
    a = np.zeros((3, length), np.uint32)
    b = np.zeros((3, length), np.uint32)
    one = np.uint32(golden.from_float(1.0, cfg))
    a[1, :], b[1, :] = one, one                        # sum of 200 ones
    a[2, :], b[2, :] = one, one
    a[2, 150] = np.uint32(cfg.nar_pattern)             # NaR in tile 2
    got = np.asarray(posit_dot.vpdot_rows(
        jnp.asarray(a).astype(cfg.storage_dtype),
        jnp.asarray(b).astype(cfg.storage_dtype), cfg,
        block_k=64)).astype(np.uint32)
    assert got[0] == 0                                 # empty quire -> 0
    assert got[1] == golden.from_float(200.0, cfg)
    assert got[2] == cfg.nar_pattern                   # NaR propagates


def test_dot_and_pgemm_zero_size_dims():
    """Empty contractions/batches: an empty quire is posit zero, empty
    batch dims produce empty outputs — no kernel launch, no crash."""
    cfg = POSIT16
    z = lambda *s: jnp.zeros(s, cfg.storage_dtype)
    got = np.asarray(ops.dot(z(3, 0), z(3, 0), cfg))
    assert got.shape == (3,) and (got == 0).all()
    assert ops.dot(z(0, 7), z(0, 7), cfg).shape == (0,)
    got = np.asarray(ops.pgemm(z(2, 0), z(0, 4), cfg))
    assert got.shape == (2, 4) and (got == 0).all()
    assert ops.pgemm(z(0, 5), z(5, 4), cfg).shape == (0, 4)
    assert ops.pgemm(z(2, 5), z(5, 0), cfg).shape == (2, 0)


def test_quire_tile_cap_is_value_error():
    """The per-tile bound surfaces as a ValueError naming the length and
    the cap — not a bare AssertionError (and the public paths never hit
    it: they tile)."""
    from repro.core import dot as dot_mod
    from repro.core.pir import decode
    cfg = POSIT16
    a = decode(jnp.zeros((1, dot_mod.MAX_DOT_LENGTH + 1), jnp.uint32), cfg)
    with pytest.raises(ValueError, match="4097.*4096"):
        dot_mod.quire_partial(a, a)
    with pytest.raises(ValueError, match="MAX_DOT_LENGTH"):
        from repro.kernels import posit_dot
        posit_dot.vpdot_rows(jnp.zeros((1, 8192), POSIT16.storage_dtype),
                             jnp.zeros((1, 8192), POSIT16.storage_dtype),
                             POSIT16, block_k=8192)


# ---------------------------------------------------------------------------
# pgemm: posit-in -> posit-out quire matmul (posit_qgemm)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [POSIT8, POSIT16,
                                 pytest.param(POSIT32, marks=_slow)],
                         ids=lambda c: c.name)
@pytest.mark.parametrize("mkn", [(5, 37, 7), (16, 64, 16),
                                 pytest.param((33, 129, 19), marks=_slow)])
def test_pgemm_matches_ref(cfg, mkn):
    m, k, n = mkn
    a = _rand_rows(cfg, (m, k), hash((cfg.nbits, mkn)) % 2 ** 31)
    w = _rand_rows(cfg, (k, n), hash((mkn, cfg.nbits)) % 2 ** 31)
    got = np.asarray(ops.pgemm(_pat(cfg, a), _pat(cfg, w), cfg))
    want = np.asarray(ref.pgemm_ref(_pat(cfg, a), _pat(cfg, w), cfg))
    assert got.dtype == want.dtype
    assert (got == want).all()


def test_pgemm_bit_identical_to_per_row_dot():
    """Acceptance criterion: pgemm(a, w)[i, j] == dot_rows(a[i], w[:, j])
    bit for bit on matching shapes."""
    cfg = POSIT16
    m, k, n = 6, 50, 4
    a = _rand_rows(cfg, (m, k), 18)
    w = _rand_rows(cfg, (k, n), 19)
    got = np.asarray(ops.pgemm(_pat(cfg, a), _pat(cfg, w), cfg))
    per_row = np.asarray(ops.dot(
        _pat(cfg, a[:, None, :]),
        _pat(cfg, np.moveaxis(w, 0, 1)[None, :, :]), cfg))
    assert (got == per_row).all()


@_slow
def test_pgemm_long_k_streams_tiles():
    """K > MAX_DOT_LENGTH streams multiple quire tiles (with ragged
    padding) and still matches the chunked reference."""
    cfg = POSIT16
    m, k, n = 2, 8200, 3
    a = _rand_rows(cfg, (m, k), 20)
    w = _rand_rows(cfg, (k, n), 21)
    got = np.asarray(ops.pgemm(_pat(cfg, a), _pat(cfg, w), cfg))
    want = np.asarray(ref.pgemm_ref(_pat(cfg, a), _pat(cfg, w), cfg))
    assert (got == want).all()


def test_pgemm_rank_polymorphic():
    cfg = POSIT8
    a = _rand_rows(cfg, (2, 3, 24), 22)
    w = _rand_rows(cfg, (24, 5), 23)
    got = np.asarray(ops.pgemm(_pat(cfg, a), _pat(cfg, w), cfg))
    assert got.shape == (2, 3, 5)
    flat = np.asarray(ops.pgemm(_pat(cfg, a.reshape(6, 24)),
                                _pat(cfg, w), cfg))
    assert (got.reshape(6, 5) == flat).all()
    vec = np.asarray(ops.pgemm(_pat(cfg, a[0, 0]), _pat(cfg, w), cfg))
    assert vec.shape == (5,) and (vec == got[0, 0]).all()
