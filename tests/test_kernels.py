"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, bit-exact."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.types import POSIT8, POSIT16, POSIT32, PositConfig
from repro.kernels import ops, ref

CODEC_CFGS = [POSIT8, POSIT16, POSIT32, PositConfig(16, 1)]
SHAPES_2D = [(8, 128), (256, 512), (100, 130), (1, 1), (3, 7)]


def _rand_f32(rng, shape):
    return (rng.standard_normal(shape) *
            np.exp(rng.uniform(-10, 10, shape))).astype(np.float32)


@pytest.mark.parametrize("cfg", CODEC_CFGS, ids=lambda c: c.name)
@pytest.mark.parametrize("shape", SHAPES_2D)
def test_codec_quantize_matches_ref(cfg, shape):
    rng = np.random.default_rng(hash((cfg.nbits, shape)) % 2 ** 31)
    x = jnp.asarray(_rand_f32(rng, shape))
    got = np.asarray(ops.quantize(x, cfg))
    want = np.asarray(ref.quantize_2d_ref(x, cfg))
    assert got.dtype == want.dtype
    assert (got == want).all()


@pytest.mark.parametrize("cfg", CODEC_CFGS, ids=lambda c: c.name)
@pytest.mark.parametrize("shape", [(16, 256), (33, 5)])
def test_codec_dequantize_matches_ref(cfg, shape):
    rng = np.random.default_rng(1)
    pats = rng.integers(0, 2 ** cfg.nbits, size=shape, dtype=np.uint64)
    p = jnp.asarray(pats.astype(np.uint32)).astype(cfg.storage_dtype)
    got = np.asarray(ops.dequantize(p, cfg))
    want = np.asarray(ref.dequantize_2d_ref(p, cfg))
    both_nan = np.isnan(got) & np.isnan(want)
    assert ((got == want) | both_nan).all()


def test_codec_roundtrip_high_rank():
    cfg = POSIT16
    rng = np.random.default_rng(2)
    x = jnp.asarray(_rand_f32(rng, (3, 5, 64)))
    p = ops.quantize(x, cfg)
    assert p.shape == x.shape
    back = ops.dequantize(p, cfg)
    # every posit16 value is f32-exact, so roundtrip == direct quantization
    want = np.asarray(ref.dequantize_2d_ref(ref.quantize_2d_ref(x, cfg), cfg))
    assert (np.asarray(back) == want).all()


@pytest.mark.parametrize("cfg", [POSIT16, POSIT8], ids=lambda c: c.name)
@pytest.mark.parametrize("mkn", [(16, 32, 8), (128, 256, 128), (33, 65, 17),
                                 (256, 128, 512)])
def test_posit_gemm_matches_ref(cfg, mkn):
    m, k, n = mkn
    rng = np.random.default_rng(hash((cfg.nbits, mkn)) % 2 ** 31)
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = rng.integers(0, 2 ** cfg.nbits, size=(k, n), dtype=np.uint64)
    # avoid NaR weights (a real checkpoint never contains NaR)
    w[w == cfg.nar_pattern] = 0
    wp = jnp.asarray(w.astype(np.uint32)).astype(cfg.storage_dtype)
    got = np.asarray(ops.gemm(a, wp, cfg))
    want = np.asarray(ref.posit_gemm_ref(a, wp, cfg))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("cfg", [POSIT32, POSIT16], ids=lambda c: c.name)
@pytest.mark.parametrize("rl", [(4, 16), (128, 64), (57, 33)])
def test_vpdot_kernel_bit_exact(cfg, rl):
    rows, length = rl
    rng = np.random.default_rng(hash((cfg.nbits, rl)) % 2 ** 31)
    a = rng.integers(0, 2 ** cfg.nbits, size=(rows, length),
                     dtype=np.uint64).astype(np.uint32)
    b = rng.integers(0, 2 ** cfg.nbits, size=(rows, length),
                     dtype=np.uint64).astype(np.uint32)
    ja = jnp.asarray(a).astype(cfg.storage_dtype)
    jb = jnp.asarray(b).astype(cfg.storage_dtype)
    got = np.asarray(ops.dot_rows(ja, jb, cfg))
    want = np.asarray(ref.vpdot_rows_ref(ja, jb, cfg))
    assert (got == want).all()
