"""End-to-end system behaviour: the framework trains, serves, and uses
the paper's posit features together."""
import dataclasses

import numpy as np
import pytest

# minutes of train/serve loops in f32 on CPU: full lane only
pytestmark = pytest.mark.slow

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import get_family
from repro.optim import adamw
from repro.runtime import train_loop


def test_train_step_improves_loss():
    """A reduced model must learn on the structured synthetic stream."""
    cfg = configs.get_config("internvl2-1b").reduced(
        compute_dtype="float32", n_visual_tokens=0)
    fam = get_family(cfg)
    opt_cfg = adamw.AdamWConfig(lr=2e-3, weight_decay=0.0)
    pipe = Pipeline(DataConfig(seed=2), cfg, global_batch=8, seq_len=64)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params, opt_cfg)
    step = jax.jit(train_loop.make_train_step(cfg, opt_cfg,
                                              total_steps=60))
    losses = []
    for i in range(60):
        params, opt, m = step(params, opt, pipe.batch_at(i),
                              jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.85, (losses[0], losses[-1])
    assert np.isfinite(losses).all()


def test_posit_moments_train_step_close_to_f32():
    cfg = configs.get_config("whisper-tiny").reduced(
        compute_dtype="float32")
    fam = get_family(cfg)
    pipe = Pipeline(DataConfig(seed=3), cfg, global_batch=2, seq_len=32)
    params = fam.init_params(jax.random.PRNGKey(1), cfg)
    outs = {}
    for name, pm in (("f32", False), ("posit", True)):
        opt_cfg = adamw.AdamWConfig(lr=1e-3, posit_moments=pm,
                                    weight_decay=0.0)
        opt = adamw.init(params, opt_cfg)
        step = jax.jit(train_loop.make_train_step(cfg, opt_cfg))
        p = params
        for i in range(5):
            p, opt, m = step(p, opt, pipe.batch_at(i),
                             jnp.asarray(i, jnp.int32))
        outs[name] = float(m["loss"])
    assert abs(outs["f32"] - outs["posit"]) < 0.05 * abs(outs["f32"])


@pytest.mark.parametrize("kv", [None, "posit16"])
def test_serve_roundtrip_with_posit_cache(kv):
    cfg = configs.get_config("gemma-7b").reduced(compute_dtype="float32")
    cfg = dataclasses.replace(cfg, kv_posit=kv)
    fam = get_family(cfg)
    params = fam.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (2, 12)), jnp.int32)
    # max_len preallocates decode headroom: the seed's prompt-sized cache
    # made every decode step clamp-overwrite the last KV slot
    cache, logits = fam.prefill(params, tokens, cfg, max_len=16)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(4):
        logits, cache = fam.decode_step(params, cache, tok, cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache["len"]) == 16


def test_posit16_kv_cache_matches_f32_generations():
    cfg0 = configs.get_config("phi3-medium-14b").reduced(
        compute_dtype="float32")
    fam = get_family(cfg0)
    params = fam.init_params(jax.random.PRNGKey(3), cfg0)
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(1, cfg0.vocab, (2, 16)), jnp.int32)

    def gen(cfg):
        cache, logits = fam.prefill(params, tokens, cfg, max_len=24)
        out = [int(t) for t in np.asarray(jnp.argmax(logits, -1))]
        outs = [out]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(6):
            logits, cache = fam.decode_step(params, cache, tok, cfg)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append([int(t) for t in np.asarray(tok)])
        return outs

    a = gen(cfg0)
    b = gen(dataclasses.replace(cfg0, kv_posit="posit16"))
    agree = np.mean([x == y for x, y in zip(np.ravel(a), np.ravel(b))])
    assert agree >= 0.85, (a, b)


def test_grad_accum_matches_full_batch():
    """grad_accum=2 must produce the same update as the full batch."""
    cfg1 = configs.get_config("whisper-tiny").reduced(
        compute_dtype="float32")
    cfg2 = dataclasses.replace(cfg1, grad_accum=2)
    fam = get_family(cfg1)
    pipe = Pipeline(DataConfig(seed=8), cfg1, global_batch=4, seq_len=32)
    params = fam.init_params(jax.random.PRNGKey(5), cfg1)
    batch = pipe.batch_at(0)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    outs = []
    for cfg in (cfg1, cfg2):
        opt = adamw.init(params, opt_cfg)
        step = jax.jit(train_loop.make_train_step(cfg, opt_cfg))
        p, o, m = step(params, opt, batch, jnp.asarray(0, jnp.int32))
        outs.append((p, float(m["loss"])))
    assert abs(outs[0][1] - outs[1][1]) < 1e-5
    for a, b in zip(jax.tree.leaves(outs[0][0]),
                    jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
