"""Hypothesis property tests for ``runtime.engine.sample_token``.

Pins the sampling contract the serving stack is built on: determinism
for a fixed key, greedy agreement in the temperature -> 0+ limit, and
in-vocab token ids for every temperature.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt); skipping instead of aborting collection")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.runtime.engine import sample_token


def _logits(seed, b, v, unique_max=False):
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((b, v)).astype(np.float32)
    if unique_max:
        # a >= 1.0 gap to the runner-up, so temperature -> 0+ must land
        # on the argmax with probability indistinguishable from 1
        peak = rng.integers(0, v, size=b)
        logits[np.arange(b), peak] = logits.max(axis=1) + 1.0
    return jnp.asarray(logits)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), temp=st.floats(0.05, 4.0),
       b=st.integers(1, 4), v=st.integers(2, 32))
def test_same_key_same_temperature_is_deterministic(seed, temp, b, v):
    logits = _logits(seed, b, v)
    key = jax.random.PRNGKey(seed % 9973)
    t1, k1 = sample_token(logits, key, temp)
    t2, k2 = sample_token(logits, key, temp)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), b=st.integers(1, 4),
       v=st.integers(2, 32))
def test_temperature_to_zero_limit_is_greedy(seed, b, v):
    """temperature -> 0+ must agree with the greedy (temperature == 0)
    argmax path, and greedy must consume no randomness (key unchanged)."""
    logits = _logits(seed, b, v, unique_max=True)
    key = jax.random.PRNGKey(seed % 9973)
    greedy, kg = sample_token(logits, key, 0.0)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.argmax(np.asarray(logits), -1))
    np.testing.assert_array_equal(np.asarray(kg), np.asarray(key))
    tiny, _ = sample_token(logits, key, 1e-6)
    np.testing.assert_array_equal(np.asarray(tiny), np.asarray(greedy))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       temp=st.one_of(st.just(0.0), st.floats(0.05, 8.0)),
       b=st.integers(1, 4), v=st.integers(2, 32))
def test_sampled_ids_always_in_vocab(seed, temp, b, v):
    logits = _logits(seed, b, v)
    tok, _ = sample_token(logits, jax.random.PRNGKey(seed % 9973), temp)
    t = np.asarray(tok)
    assert t.shape == (b,) and t.dtype == np.int32
    assert ((t >= 0) & (t < v)).all()
