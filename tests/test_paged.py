"""Paged KV cache regression tests.

The load-bearing invariant of the paged memory model: swapping the dense
``slots x max_len`` cache for a block arena + per-row block tables must
be invisible to the tokens.  Pinned here on all three transformer
attention lanes (dense, MLA, sliding-window — where the ring buffer
becomes block recycling), through ``Engine.generate`` (scan AND the
per-step reference loop) and through the continuous-batching scheduler,
which must reproduce the compaction scheduler's streams token-for-token
and schedule-for-schedule while the arena reports strictly fewer bytes
than ``slots x max_len``.  Plus the ``BlockPool`` allocator invariants
(no leak / double-alloc / over-capacity on random traces) and the
explicit pattern/metadata leaf tagging.
"""
import dataclasses
import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.compress import kvcache as kvc
from repro.models import get_family
from repro.models import transformer as T
from repro.runtime.engine import Engine
from repro.runtime.scheduler import Scheduler

LANES = ["dense", "mla", "window"]


def _cfg(lane, **kw):
    if lane == "mla":
        return configs.get_config("minicpm3-4b").reduced(
            compute_dtype="float32", **kw)
    cfg = configs.get_config("phi3-medium-14b").reduced(
        compute_dtype="float32", **kw)
    if lane == "window":
        cfg = dataclasses.replace(cfg, sliding_window=8, attn_chunk_kv=8)
    return cfg


def _params(cfg, seed=0):
    return get_family(cfg).init_params(jax.random.PRNGKey(seed), cfg)


# ---------------------------------------------------------------------------
# BlockPool allocator invariants (property-style, stdlib random)
# ---------------------------------------------------------------------------

def test_block_pool_random_traces_never_leak_or_double_allocate():
    """Random submit/retire traces: every handed-out id is unique among
    live allocations, usage never exceeds the arena, frees return
    capacity exactly, and the high-water mark is faithful."""
    rng = random.Random(1234)
    for _ in range(50):
        n_blocks = rng.randint(1, 64)
        pool = kvc.BlockPool(n_blocks)
        live = {}                       # handle -> ids
        peak = 0
        for step in range(200):
            assert pool.n_free + pool.in_use == n_blocks   # conservation
            if live and (rng.random() < 0.4 or pool.n_free == 0):
                ids = live.pop(rng.choice(list(live)))
                pool.free(ids)
            else:
                n = rng.randint(0, n_blocks)
                if n > pool.n_free:
                    with pytest.raises(MemoryError):
                        pool.alloc(n)
                    continue
                ids = pool.alloc(n)
                assert len(set(ids)) == len(ids)
                flat = [i for v in live.values() for i in v]
                assert not set(ids) & set(flat)            # no double-alloc
                assert all(0 <= i < n_blocks for i in ids)
                live[step] = ids
            in_use = sum(len(v) for v in live.values())
            assert pool.in_use == in_use
            peak = max(peak, in_use)
            assert pool.peak_in_use == peak
        for ids in live.values():
            pool.free(ids)
        assert pool.n_free == n_blocks and pool.in_use == 0


def test_block_pool_rejects_double_free_and_foreign_ids():
    pool = kvc.BlockPool(4)
    ids = pool.alloc(2)
    pool.free(ids)
    with pytest.raises(ValueError, match="not allocated"):
        pool.free(ids)                  # double free
    with pytest.raises(ValueError, match="not allocated"):
        pool.free([99])                 # never existed


# ---------------------------------------------------------------------------
# arena sanitizer (BlockPool(sanitize=True) + device poisoning)
# ---------------------------------------------------------------------------

def test_sanitizer_diagnoses_double_free_use_after_free_and_cow_skip():
    """Seeded misuse, one of each class the sanitizer exists to catch:
    double free, use-after-free (write/read/share of a freed id), a
    COW-skip (write into a refcount > 1 block), and a wild id."""
    pool = kvc.BlockPool(6, sanitize=True)
    a, b, c = pool.alloc(3)

    assert pool.free([a]) == [a]        # physically reclaimed
    with pytest.raises(kvc.BlockSanitizerError, match="double free"):
        pool.free([a])
    with pytest.raises(kvc.BlockSanitizerError, match="use-after-free"):
        pool.check_write([a])
    with pytest.raises(kvc.BlockSanitizerError, match="use-after-free"):
        pool.check_read([a])
    with pytest.raises(kvc.BlockSanitizerError, match="use-after-free"):
        pool.share([a])

    pool.share([b])                     # now refcount 2: COW required
    with pytest.raises(kvc.BlockSanitizerError, match="COW violation"):
        pool.check_write([b])
    pool.check_read([b])                # reads of shared blocks are fine
    pool.release([b])
    pool.check_write([b])               # exclusive again

    with pytest.raises(kvc.BlockSanitizerError, match="wild"):
        pool.check_write([42])

    # free returns ONLY physically reclaimed ids (refcount hit zero)
    pool.share([c])
    assert pool.free([c]) == []
    assert pool.free([c]) == [c]

    # reallocation clears the freed mark: the id is healthy again
    fresh = pool.alloc(1)
    pool.check_write(fresh)
    assert pool.allocated_ids() == sorted([b] + fresh)


def test_sanitizer_off_keeps_plain_allocator_errors():
    pool = kvc.BlockPool(4)             # sanitize defaults to False
    ids = pool.alloc(1)
    assert pool.free(ids) == ids
    with pytest.raises(ValueError) as ei:
        pool.free(ids)
    assert not isinstance(ei.value, kvc.BlockSanitizerError)


def test_paged_poison_blocks_patterns_and_sentinel_drop():
    """Device half: float leaves poison to a finite absurd value (NaN
    would leak through masked-softmax zeros as 0 * NaN), unsigned posit
    pattern leaves to maxpos, untouched blocks stay intact, and the
    sentinel id drops its write."""
    from repro.models import layers as L
    arena_f = jnp.ones((2, 4, 3, 2, 2), jnp.float32)       # (L,nb,bs,H,D)
    out = L.paged_poison_blocks(arena_f, [1, 3])
    assert np.all(np.asarray(out[:, (1, 3)]) == -1e30)
    assert np.all(np.asarray(out[:, (0, 2)]) == 1.0)
    assert np.all(np.isfinite(np.asarray(out)))

    arena_u = jnp.zeros((2, 4, 3, 2, 2), jnp.uint8)
    out = L.paged_poison_blocks(arena_u, [0])
    assert np.all(np.asarray(out[:, 0]) == 0x7F)           # posit8 maxpos
    assert np.all(np.asarray(out[:, 1:]) == 0)

    out = L.paged_poison_blocks(arena_f, [4])              # sentinel: drop
    np.testing.assert_array_equal(np.asarray(out), np.asarray(arena_f))


# ---------------------------------------------------------------------------
# token identity: paged engine == linear/ring engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lane", LANES)
def test_paged_generate_token_identity(lane):
    """Ragged batch, generation long enough to cross block boundaries
    (and, on the window lane, to recycle blocks through full ring
    wraparounds): the paged engine must emit byte-identical tokens to
    the linear/ring-buffer engine, in the scan AND the per-step loop."""
    cfg = _cfg(lane)
    params = _params(cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab, n).tolist() for n in (7, 10, 4)]

    lin = Engine(cfg, params, max_len=32, seed=0)
    pag = Engine(cfg, params, max_len=32, seed=0, paged=True, block_size=4)
    ref = lin.generate(prompts, 14).tokens
    got = pag.generate(prompts, 14).tokens
    np.testing.assert_array_equal(got, ref)
    np.testing.assert_array_equal(
        pag.generate_stepwise(prompts, 14).tokens, ref)
    # the engine records the arena's actual high-water mark
    assert 0 < pag.pool.peak_in_use <= pag.pool.n_blocks


def test_paged_generate_token_identity_posit_kv():
    """The paged layout must compose with the posit KV codec: patterns
    round-trip through arena blocks bit-identically."""
    cfg = _cfg("dense", kv_posit="posit8")
    params = _params(cfg)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(1, cfg.vocab, n).tolist() for n in (6, 9)]
    ref = Engine(cfg, params, max_len=32, seed=0).generate(prompts, 10)
    pag = Engine(cfg, params, max_len=32, seed=0, paged=True,
                 block_size=4).generate(prompts, 10)
    np.testing.assert_array_equal(pag.tokens, ref.tokens)


# ---------------------------------------------------------------------------
# paged scheduler == compaction scheduler, with fewer cache bytes
# ---------------------------------------------------------------------------

def _run_sched(sched, prompts, gens):
    rids = [sched.submit(p, g) for p, g in zip(prompts, gens)]
    done = sched.run(max_rounds=200)
    return rids, done


@pytest.mark.parametrize("lane", ["dense", "window"])
def test_paged_scheduler_matches_compaction_scheduler(lane):
    """Same submissions through a two-slot pool: the paged scheduler
    (no ``compact`` anywhere) must match the PR 4 compaction scheduler
    token-for-token AND step-for-step, while its arena — sized below
    ``slots x table_width`` — reports strictly fewer cache bytes than
    the dense pool."""
    cfg = _cfg(lane)
    params = _params(cfg)
    rng = np.random.default_rng(3)
    plens = [5, 9, 3, 7, 4, 6]
    gens = [4, 8, 4, 8, 4, 8]
    prompts = [rng.integers(1, cfg.vocab, n).tolist() for n in plens]

    lin = Scheduler(Engine(cfg, params, max_len=32, seed=0),
                    n_slots=2, chunk_size=4)
    rids_l, done_l = _run_sched(lin, prompts, gens)

    nb = 10 if lane == "dense" else 0    # dense: strictly below 2*8 worst
    # sanitize=True: the full arena sanitizer (pre-chunk check_read/
    # check_write gates + poisoning of reclaimed blocks) must be
    # invisible to the tokens AND report a leak-free trace
    pag = Scheduler(Engine(cfg, params, max_len=32, seed=0, paged=True,
                           block_size=4, n_blocks=nb, sanitize=True),
                    n_slots=2, chunk_size=4)
    rids_p, done_p = _run_sched(pag, prompts, gens)

    for a, b in zip(rids_l, rids_p):
        np.testing.assert_array_equal(done_p[b].tokens, done_l[a].tokens)
        assert done_p[b].admitted_step == done_l[a].admitted_step
        assert done_p[b].finished_step == done_l[a].finished_step
    if lane == "dense":
        assert kvc.cache_report(pag.cache)["bytes"] < \
            kvc.cache_report(lin.cache)["bytes"]
    # no block leaked once everything retired
    assert pag.pool.in_use == 0 and pag._outstanding == 0
    assert pag.n_leaked == 0 and not pag.leak_report()
    assert pag.pool.n_sanitizer_checks > 0    # the gates actually ran


def test_paged_scheduler_defers_admission_when_pool_is_tight():
    """A pool too small to hold every concurrent request must DEFER
    admissions (FIFO) instead of corrupting or failing — each stream
    still matches its isolated reference."""
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(7)
    plens = [5, 9, 3, 7]
    gens = [4, 8, 4, 8]
    prompts = [rng.integers(1, cfg.vocab, n).tolist() for n in plens]
    ref_eng = Engine(cfg, params, max_len=32, seed=0)
    refs = [ref_eng.generate([p], g).tokens[0]
            for p, g in zip(prompts, gens)]

    # 5 blocks of 4 slots: roughly one request's worst case at a time
    sched = Scheduler(Engine(cfg, params, max_len=32, seed=0, paged=True,
                             block_size=4, n_blocks=5),
                      n_slots=2, chunk_size=4)
    rids, done = _run_sched(sched, prompts, gens)
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(done[rid].tokens, ref)
    assert sched.pool.peak_in_use <= 5


def test_paged_scheduler_rejects_request_larger_than_pool():
    cfg = _cfg("dense")
    params = _params(cfg)
    sched = Scheduler(Engine(cfg, params, max_len=32, seed=0, paged=True,
                             block_size=4, n_blocks=3),
                      n_slots=1, chunk_size=4)
    with pytest.raises(ValueError, match="block"):
        sched.submit(list(range(1, 13)), 8)   # needs ceil(23/4)=6 > 3


# ---------------------------------------------------------------------------
# guarded writes / capacity
# ---------------------------------------------------------------------------

def test_paged_decode_past_capacity_raises_eagerly():
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(9)
    eng = Engine(cfg, params, max_len=8, seed=0, paged=True, block_size=4)
    prompts = [rng.integers(1, cfg.vocab, 6).tolist()]
    cache, logits, _ = eng.prefill(prompts, reserve_tokens=2)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(2):                       # positions 6, 7 fit
        logits, cache = T.decode_step(params, cache, tok, cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    with pytest.raises(ValueError, match="capacity"):
        T.decode_step(params, cache, tok, cfg)   # position 8 == max_len


def test_paged_sentinel_tables_drop_writes():
    """A released row's sentinel table entries must route decode writes
    into the drop lane: the arena is bit-unchanged afterwards."""
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(10)
    eng = Engine(cfg, params, max_len=16, seed=0, paged=True, block_size=4)
    cache, logits, _ = eng.prefill(
        [rng.integers(1, cfg.vocab, 5).tolist()], reserve_tokens=4)
    released = kvc.paged_release_rows(cache, jnp.asarray([True]))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    _, after = T.decode_step(params, released, tok, cfg,
                             active=jnp.asarray([False]))
    np.testing.assert_array_equal(np.asarray(after["k"]),
                                  np.asarray(released["k"]))
    assert int(after["lens"][0]) == 0        # frozen, not advanced


# ---------------------------------------------------------------------------
# explicit pattern/metadata leaf tagging
# ---------------------------------------------------------------------------

def test_scale_cache_leaves_paged_block_tables_alone():
    cfg = _cfg("dense", kv_posit="posit16")
    params = _params(cfg)
    rng = np.random.default_rng(11)
    eng = Engine(cfg, params, max_len=16, seed=0, paged=True, block_size=4)
    cache, _, _ = eng.prefill([rng.integers(1, cfg.vocab, 6).tolist()])
    scaled = kvc.scale_cache(cache, 0.5, "posit16")
    np.testing.assert_array_equal(np.asarray(scaled["block_tables"]),
                                  np.asarray(cache["block_tables"]))
    np.testing.assert_array_equal(np.asarray(scaled["lens"]),
                                  np.asarray(cache["lens"]))
    assert not (np.asarray(scaled["k"]) == np.asarray(cache["k"])).all()


def test_unknown_unsigned_leaf_raises_instead_of_guessing():
    """The old dtype-sniffing heuristic would have 'scaled' any unsigned
    bookkeeping leaf as posit patterns; the explicit schema refuses."""
    cache = {"k": jnp.zeros((4, 8), jnp.uint16),
             "my_table": jnp.zeros((4,), jnp.uint32)}
    with pytest.raises(ValueError, match="my_table"):
        kvc.scale_cache(cache, 0.5, "posit16")
    with pytest.raises(ValueError, match="my_table"):
        kvc.dequantize_cache(cache, "posit16")
    # ...and quantize refuses to silently SKIP an unregistered float
    # leaf (the codec would otherwise quietly stop compressing it)
    with pytest.raises(ValueError, match="conv_state"):
        kvc.quantize_cache({"k": jnp.zeros((4, 8), jnp.float32),
                            "conv_state": jnp.zeros((4,), jnp.float32)},
                           "posit16")


def test_prefill_paged_override_needs_paged_engine():
    cfg = _cfg("dense")
    params = _params(cfg)
    eng = Engine(cfg, params, max_len=16, seed=0)     # dense engine
    with pytest.raises(ValueError, match="paged=True"):
        eng.prefill([[1, 2, 3]], paged=True)


def test_linear_surgery_ops_reject_paged_caches():
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(12)
    eng = Engine(cfg, params, max_len=16, seed=0, paged=True, block_size=4)
    cache, _, _ = eng.prefill([rng.integers(1, cfg.vocab, 5).tolist()])
    with pytest.raises(ValueError, match="paged"):
        kvc.compact(cache, target_len=8)
    with pytest.raises(ValueError, match="paged"):
        kvc.reset_slots(cache, jnp.asarray([True]))


# ---------------------------------------------------------------------------
# full ragged-trace comparison (slow, main lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("lane", LANES)
def test_paged_trace_identity_all_lanes(lane):
    """A full Poisson trace through both schedulers: identical
    completions on every lane, plus the MLA lane's scheduler identity
    (the fast test covers dense/window)."""
    from repro.launch.serve import drive_trace, poisson_trace
    cfg = _cfg(lane)
    params = _params(cfg)
    trace = poisson_trace(np.random.default_rng(21), 10, 0.8,
                          cfg.vocab, 10, 8)
    max_len = 10 + 8 - 1 + 4

    lin = Scheduler(Engine(cfg, params, max_len=max_len, seed=0),
                    n_slots=2, chunk_size=4)
    done_l, _ = drive_trace(lin, trace)
    pag = Scheduler(Engine(cfg, params, max_len=max_len, seed=0,
                           paged=True, block_size=4),
                    n_slots=2, chunk_size=4)
    done_p, _ = drive_trace(pag, trace)

    assert done_l.keys() == done_p.keys()
    for rid in done_l:
        np.testing.assert_array_equal(done_p[rid].tokens,
                                      done_l[rid].tokens)
        assert done_p[rid].finished_step == done_l[rid].finished_step
    assert pag.pool.in_use == 0
