"""Fused paged-decode attention kernel tests.

The tentpole invariant: ``kernels/posit_paged_attn.py`` — one Pallas
kernel walking each row's block table with a sequential grid dimension,
posit decode in-kernel, online-softmax state carried in VMEM scratch —
must be invisible to the numbers.  Pinned three ways:

* layer level, fused vs the gather+``decode_attention`` reference on
  the dense/GQA, sliding-window block-ring (including wraparound) and
  MLA latent lanes, across posit8/posit16/f32 KV and ragged ``lens``
  (fast seeded subset here, ``slow``-marked exhaustive sweep below);
* engine level, token identity fused vs gather vs the LINEAR ring on
  all three lanes through ``Engine.generate``, and through the
  scheduler's preemption-restart path;
* the all-masked-row regression: a row with no valid slot (an inactive
  or preempted scheduler slot whose sentinel table entries alias real
  blocks through the gather clamp) must yield EXACT ZEROS — the old
  ``exp(_NEG - _NEG) == 1`` path returned a uniform average of garbage
  — on the linear path, the paged gather path and the fused kernel.

Everything runs the kernel in Pallas interpret mode (CPU container);
the CI fast lane executes this file explicitly.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.kernels import ops as kops
from repro.kernels.posit_paged_attn import paged_decode_kv_bytes
from repro.models import get_family
from repro.models import layers as L
from repro.runtime.engine import Engine
from repro.runtime.scheduler import Scheduler

LANES = ["dense", "mla", "window"]
KV_FORMATS = [None, "posit16", "posit8"]


def _cfg(lane, **kw):
    if lane == "mla":
        return configs.get_config("minicpm3-4b").reduced(
            compute_dtype="float32", **kw)
    cfg = configs.get_config("phi3-medium-14b").reduced(
        compute_dtype="float32", **kw)
    if lane == "window":
        cfg = dataclasses.replace(cfg, sliding_window=8, attn_chunk_kv=8)
    return cfg


def _params(cfg, seed=0):
    return get_family(cfg).init_params(jax.random.PRNGKey(seed), cfg)


def _arena(rng, shape, kv):
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    return kops.quantize(x, L.pcfg(kv)) if kv else x


# ---------------------------------------------------------------------------
# layer-level identity: fused kernel vs gather + decode_attention
# ---------------------------------------------------------------------------

def _dense_case(rng, kv, window, lens):
    """A small dense/window paged-decode problem with one sentinel tail."""
    cfg = dataclasses.replace(_cfg("window" if window else "dense"),
                              kv_posit=kv)
    g, h, d, bs = cfg.n_kv_heads, cfg.n_heads, cfg.head_dim, 4
    lens = jnp.asarray(lens, jnp.int32)
    b = lens.shape[0]
    w = L.paged_window_blocks(window, bs) if window else 5
    nb = b * w
    tables = jnp.arange(nb, dtype=jnp.int32).reshape(b, w)
    tables = tables.at[-1, -1].set(nb)          # unallocated tail: sentinel
    k_arena = _arena(rng, (nb, bs, g, d), kv)
    v_arena = _arena(rng, (nb, bs, g, d), kv)
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    return cfg, q, k_arena, v_arena, tables, lens


def _dense_both(case, window):
    cfg, q, k_arena, v_arena, tables, lens = case
    return [L.decode_attention_paged(
        q, k_arena, v_arena, tables, lens, cfg=cfg, kv_posit=cfg.kv_posit,
        window=window, kernel=kern) for kern in ("fused", "gather")]


@pytest.mark.parametrize("kv", [None, "posit16"])
def test_fused_matches_gather_dense(kv):
    rng = np.random.default_rng(5)
    fused, ref = _dense_both(
        _dense_case(rng, kv, 0, [9, 2, 17]), 0)
    np.testing.assert_allclose(fused, ref, atol=2e-6, rtol=1e-5)


@pytest.mark.parametrize("kv", [None, "posit8"])
def test_fused_matches_gather_ring_wraparound(kv):
    """Window lane with frontiers past the ring capacity: the stale half
    of the frontier's own block must be masked identically in-kernel."""
    window = 8
    rng = np.random.default_rng(6)
    # 13 and 22 both wrap the W=3-block ring (capacity 12 slots); 2 does
    # not — the same kernel grid must honor both regimes per-row
    fused, ref = _dense_both(
        _dense_case(rng, kv, window, [13, 2, 22]), window)
    np.testing.assert_allclose(fused, ref, atol=2e-6, rtol=1e-5)


@pytest.mark.parametrize("kv", [None, "posit16"])
def test_fused_matches_gather_mla(kv):
    rng = np.random.default_rng(7)
    cfg = dataclasses.replace(_cfg("mla"), kv_posit=kv)
    h, rank, rope, bs, w = (cfg.n_heads, cfg.kv_lora_rank,
                            cfg.qk_rope_dim, 4, 5)
    lens = jnp.array([9, 14, 0], jnp.int32)
    b = lens.shape[0]
    nb = b * w
    tables = jnp.arange(nb, dtype=jnp.int32).reshape(b, w)
    tables = tables.at[0, -1].set(nb)
    c_arena = _arena(rng, (nb, bs, rank), kv)
    r_arena = _arena(rng, (nb, bs, rope), kv)
    qe = jnp.asarray(rng.normal(size=(b, h, rank)), jnp.float32)
    qr = jnp.asarray(rng.normal(size=(b, h, rope)), jnp.float32)
    outs = [L.decode_attention_paged_mla(
        qe, qr, c_arena, r_arena, tables, lens, cfg=cfg,
        kv_posit=kv, kernel=kern) for kern in ("fused", "gather")]
    np.testing.assert_allclose(outs[0], outs[1], atol=2e-6, rtol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("kv", KV_FORMATS)
@pytest.mark.parametrize("window", [0, 8])
def test_fused_matches_gather_exhaustive(kv, window):
    """Exhaustive ragged sweep: every frontier from empty to deep ring
    wraparound, all KV formats, both dense and window lanes."""
    rng = np.random.default_rng(8)
    for lo in range(0, 24, 3):
        lens = [lo, lo + 1, lo + 7]
        fused, ref = _dense_both(
            _dense_case(rng, kv, window, lens), window)
        np.testing.assert_allclose(fused, ref, atol=2e-6, rtol=1e-5,
                                   err_msg=f"kv={kv} lens={lens}")


# ---------------------------------------------------------------------------
# all-masked-row regression (the bug this kernel builds on)
# ---------------------------------------------------------------------------

def test_all_masked_row_returns_zeros_linear():
    """A row whose every cache slot is masked (cache_len 0) used to get
    ``exp(_NEG - _NEG) == 1`` everywhere — a uniform average of garbage
    cache content.  It must be exact zeros, and rows WITH valid slots
    must be bit-identical to before the guard."""
    cfg = _cfg("dense")
    rng = np.random.default_rng(9)
    b, t, g, h, d = 2, 8, cfg.n_kv_heads, cfg.n_heads, cfg.head_dim
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    # garbage-heavy cache: any leak through the softmax is loud
    k = jnp.asarray(rng.normal(size=(b, t, g, d)) * 1e3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, g, d)) * 1e3, jnp.float32)
    out = L.decode_attention(q, k, v, jnp.array([5, 0], jnp.int32),
                             cfg=cfg)
    assert float(jnp.abs(out[1]).max()) == 0.0
    assert float(jnp.abs(out[0]).max()) > 0.0
    solo = L.decode_attention(q[:1], k[:1], v[:1],
                              jnp.array([5], jnp.int32), cfg=cfg)
    np.testing.assert_array_equal(out[0], solo[0])


@pytest.mark.parametrize("kernel", ["gather", "fused"])
def test_all_masked_row_returns_zeros_paged(kernel):
    """An all-sentinel block table (a preempted slot after its blocks
    were released) aliases arbitrary real blocks through the gather
    clamp / the kernel's DMA clamp; both paths must return zeros."""
    cfg = _cfg("dense")
    rng = np.random.default_rng(10)
    g, h, d, bs, w, nb = cfg.n_kv_heads, cfg.n_heads, cfg.head_dim, 4, 3, 6
    k_arena = _arena(rng, (nb, bs, g, d), None) * 1e3
    v_arena = _arena(rng, (nb, bs, g, d), None) * 1e3
    q = jnp.asarray(rng.normal(size=(2, 1, h, d)), jnp.float32)
    tables = jnp.stack([jnp.arange(w, dtype=jnp.int32),
                        jnp.full((w,), nb, jnp.int32)])   # row 1: sentinel
    out = L.decode_attention_paged(
        q, k_arena, v_arena, tables, jnp.array([5, 5], jnp.int32),
        cfg=cfg, kernel=kernel)
    assert float(jnp.abs(out[1]).max()) == 0.0
    assert float(jnp.abs(out[0]).max()) > 0.0


@pytest.mark.parametrize("kernel", ["gather", "fused"])
def test_all_masked_row_returns_zeros_mla(kernel):
    cfg = _cfg("mla")
    rng = np.random.default_rng(11)
    h, rank, rope, bs, w, nb = (cfg.n_heads, cfg.kv_lora_rank,
                                cfg.qk_rope_dim, 4, 3, 6)
    c_arena = _arena(rng, (nb, bs, rank), None) * 1e3
    r_arena = _arena(rng, (nb, bs, rope), None) * 1e3
    qe = jnp.asarray(rng.normal(size=(2, h, rank)), jnp.float32)
    qr = jnp.asarray(rng.normal(size=(2, h, rope)), jnp.float32)
    tables = jnp.stack([jnp.arange(w, dtype=jnp.int32),
                        jnp.full((w,), nb, jnp.int32)])
    out = L.decode_attention_paged_mla(
        qe, qr, c_arena, r_arena, tables, jnp.array([5, 5], jnp.int32),
        cfg=cfg, kernel=kernel)
    assert float(jnp.abs(out[1]).max()) == 0.0
    assert float(jnp.abs(out[0]).max()) > 0.0


# ---------------------------------------------------------------------------
# engine-level token identity: fused vs gather vs the linear ring
# ---------------------------------------------------------------------------

def _gen_tokens(cfg, params, prompts, gen, **eng_kw):
    eng = Engine(cfg, params, max_len=32, seed=0, **eng_kw)
    return eng.generate(prompts, gen).tokens


@pytest.mark.parametrize("lane", LANES)
def test_engine_fused_token_identity(lane):
    """Fused paged decode == gather paged decode == the LINEAR cache
    (ring buffer on the window lane), token for token, on ragged
    prompts with generation long enough to wrap the block ring."""
    cfg = _cfg(lane, kv_posit="posit16")
    params = _params(cfg)
    rng = np.random.default_rng(12)
    prompts = [list(rng.integers(1, cfg.vocab, size=n)) for n in (5, 9, 3)]
    gen = 20              # window=8, block=4: wraps the W=3 ring twice
    linear = _gen_tokens(cfg, params, prompts, gen)
    gather = _gen_tokens(cfg, params, prompts, gen, paged=True,
                         block_size=4, decode_kernel="gather")
    fused = _gen_tokens(cfg, params, prompts, gen, paged=True,
                        block_size=4, decode_kernel="fused")
    np.testing.assert_array_equal(gather, linear)
    np.testing.assert_array_equal(fused, gather)


@pytest.mark.slow
@pytest.mark.parametrize("lane", LANES)
@pytest.mark.parametrize("kv", KV_FORMATS)
def test_engine_fused_token_identity_exhaustive(lane, kv):
    cfg = _cfg(lane, kv_posit=kv)
    params = _params(cfg)
    rng = np.random.default_rng(13)
    prompts = [list(rng.integers(1, cfg.vocab, size=n))
               for n in (7, 2, 11, 4)]
    gather = _gen_tokens(cfg, params, prompts, 20, paged=True,
                         block_size=4, decode_kernel="gather")
    fused = _gen_tokens(cfg, params, prompts, 20, paged=True,
                        block_size=4, decode_kernel="fused")
    np.testing.assert_array_equal(fused, gather)


def test_fused_survives_preemption_restart():
    """Preemption-by-block-release then restart, decoding through the
    fused kernel: the preempted request's stream must match isolated
    greedy generation and no arena block may leak (the released rows'
    all-sentinel tables hit the kernel's masked path every step)."""
    cfg = _cfg("dense", kv_posit="posit16")
    params = _params(cfg)
    rng = np.random.default_rng(14)
    p_a = rng.integers(1, cfg.vocab, 8).tolist()
    p_b = rng.integers(1, cfg.vocab, 8).tolist()
    ref_eng = Engine(cfg, params, max_len=32, paged=True, block_size=4,
                     decode_kernel="fused")
    ref_a = ref_eng.generate([p_a], 8).tokens[0]
    ref_b = ref_eng.generate([p_b], 8).tokens[0]

    # 6-block pool: two requests can never be resident together, so the
    # deadline submission MUST preempt the best-effort one
    eng = Engine(cfg, params, max_len=32, paged=True, block_size=4,
                 n_blocks=6, sanitize=True, decode_kernel="fused")
    sched = Scheduler(eng, n_slots=2, chunk_size=4, chunked_prefill=True)
    ra = sched.submit(p_a, 8)
    sched.step()
    rb = sched.submit(p_b, 8, deadline=20)
    done = sched.run(max_rounds=300)
    assert sched.n_preempted >= 1
    np.testing.assert_array_equal(done[ra].tokens, ref_a)
    np.testing.assert_array_equal(done[rb].tokens, ref_b)
    assert sched.n_leaked == 0 and not sched.leak_report()


# ---------------------------------------------------------------------------
# decode-bytes ledger
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lane", LANES)
@pytest.mark.parametrize("kv", KV_FORMATS)
def test_fused_moves_strictly_fewer_bytes(lane, kv):
    """The point of the kernel: one pattern-width pass over KV instead
    of gather + dequant round-trips, for every lane and KV format."""
    cfg = _cfg(lane, kv_posit=kv)
    fused = paged_decode_kv_bytes(cfg, table_width=8, block_size=4,
                                  kernel="fused")
    gather = paged_decode_kv_bytes(cfg, table_width=8, block_size=4,
                                   kernel="gather")
    assert 0 < fused < gather
    if kv == "posit8":        # posit8 patterns: half an f16 cache's bytes
        f16_read = paged_decode_kv_bytes(
            dataclasses.replace(cfg, kv_posit=None), table_width=8,
            block_size=4, kernel="fused") // 2
        assert fused * 2 == f16_read
