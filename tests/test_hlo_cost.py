"""Trip-count-aware HLO cost analyzer: validation against known kernels."""
import subprocess
import sys
import os
import json

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import analyze
from repro.launch.hlo_analysis import collective_bytes

results = {}

# 1. scan of matmuls: flops must be ~ 2*M*N*K*T
def f(x, w):
    def body(c, _):
        return jnp.tanh(c @ w), None
    out, _ = jax.lax.scan(body, x, None, length=17)
    return out.sum()

comp = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
r = analyze(comp.as_text())
results["scan_flops"] = r["flops"]
results["scan_expected"] = 2 * 64 * 64 * 64 * 17

# 2. sharded: per-chip flops ~ global/8; collectives trip-multiplied
mesh = jax.make_mesh((8,), ("model",))
ws = NamedSharding(mesh, P(None, "model"))
comp2 = jax.jit(f, in_shardings=(ws, ws)).lower(
    jax.ShapeDtypeStruct((64, 64), jnp.float32),
    jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
r2 = analyze(comp2.as_text())
results["sharded_flops"] = r2["flops"]
results["sharded_expected"] = 2 * 64 * 64 * 64 * 17 / 8
results["coll_trip"] = r2["collectives"].get("all-gather", 0)
results["coll_once"] = collective_bytes(comp2.as_text()).get("all-gather", 0)

# 3. nested scans multiply
def g(x):
    def outer(c, _):
        def inner(d, _):
            return d * 1.5 + 1.0, None
        d, _ = jax.lax.scan(inner, c, None, length=5)
        return d, None
    out, _ = jax.lax.scan(outer, x, None, length=7)
    return out.sum()

comp3 = jax.jit(g).lower(
    jax.ShapeDtypeStruct((128,), jnp.float32)).compile()
r3 = analyze(comp3.as_text())
results["nested_flops"] = r3["flops"]
results["nested_expected_min"] = 128 * 2 * 5 * 7   # mul+add per element

print(json.dumps(results))
"""


def test_trip_aware_cost_analyzer():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    # flops within 5% of the closed form (elementwise ops add a little)
    assert abs(r["scan_flops"] - r["scan_expected"]) \
        < 0.05 * r["scan_expected"], r
    assert abs(r["sharded_flops"] - r["sharded_expected"]) \
        < 0.10 * r["sharded_expected"], r
    # the collective inside the scan counts 17x the once-through number
    assert r["coll_trip"] >= 16 * r["coll_once"], r
    # nested loops multiply (7 * 5)
    assert r["nested_flops"] >= r["nested_expected_min"], r
