"""Data pipeline: determinism, statelessness, shapes."""
import numpy as np

from repro.data.pipeline import DataConfig, Pipeline
from repro.models.config import ModelConfig


def _pipe(**kw):
    cfg = ModelConfig(vocab=512)
    return Pipeline(DataConfig(**kw), cfg, global_batch=4, seq_len=32)


def test_batches_deterministic_and_index_addressable():
    p1 = _pipe(seed=7)
    p2 = _pipe(seed=7)
    b1 = p1.batch_at(123)
    b2 = p2.batch_at(123)                  # fresh object, same batch
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_different_steps_different_batches():
    p = _pipe(seed=7)
    a = np.asarray(p.batch_at(0)["tokens"])
    b = np.asarray(p.batch_at(1)["tokens"])
    assert (a != b).any()


def test_resume_equals_uninterrupted_run():
    """Stateless indexing: consuming [0..9] then 'resuming' at 5 yields
    exactly the batches an uninterrupted run would see."""
    p = _pipe(seed=3)
    full = [np.asarray(p.batch_at(i)["tokens"]) for i in range(10)]
    resumed = [np.asarray(_pipe(seed=3).batch_at(i)["tokens"])
               for i in range(5, 10)]
    for a, b in zip(full[5:], resumed):
        np.testing.assert_array_equal(a, b)


def test_tokens_in_vocab_range():
    p = _pipe(seed=11)
    t = np.asarray(p.batch_at(2)["tokens"])
    assert t.min() >= 0 and t.max() < 512
    assert t.dtype == np.int32


def test_bytes_corpus_mode(tmp_path):
    path = tmp_path / "corpus.txt"
    path.write_text("the quick brown fox jumps over the lazy dog " * 50)
    cfg = ModelConfig(vocab=256)
    p = Pipeline(DataConfig(source="bytes", path=str(path)), cfg, 2, 16)
    t = np.asarray(p.batch_at(0)["tokens"])
    assert t.shape == (2, 16)
    assert t.max() < 256
