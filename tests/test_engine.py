"""Serving-engine regression tests.

The seed's decode path was numerically wrong: prefill returned a cache
whose time axis equalled the prompt length, and decode_step wrote new
K/V at absolute position ``pos`` with ``dynamic_update_slice_in_dim``,
whose index-CLAMPING semantics silently overwrote the final cache slot
on every step past the first.  These tests pin the fix on all three
transformer lanes (dense, MLA, sliding-window ring buffer), the guarded
out-of-capacity behaviour, the one-scan decode, and ragged batching.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro import configs
from repro.models import get_family
from repro.models import layers as L
from repro.models import transformer as T
from repro.runtime.engine import Engine


def _dense_cfg(**kw):
    return configs.get_config("phi3-medium-14b").reduced(
        compute_dtype="float32", **kw)


def _mla_cfg(**kw):
    return configs.get_config("minicpm3-4b").reduced(
        compute_dtype="float32", **kw)


def _params(cfg, seed=0):
    return get_family(cfg).init_params(jax.random.PRNGKey(seed), cfg)


def _seed_decode_step(params, cache, token, cfg):
    """The SEED's dense decode semantics, reproduced verbatim as the
    broken reference: absolute-position writes that clamp onto the last
    slot once ``pos`` reaches the cache capacity."""
    pos = cache["len"]
    x = params["tok_embed"][token][:, None, :].astype(L.cdtype(cfg))

    def body(h, layer):
        lp, k_c, v_c = layer
        p = lp["attn"]
        b = h.shape[0]
        xin = L.rms_norm(lp["ln1"], h, cfg)
        q = L.dense(p["wq"], xin, cfg).reshape(
            b, 1, cfg.n_heads, cfg.head_dim)
        k = L.dense(p["wk"], xin, cfg).reshape(
            b, 1, cfg.n_kv_heads, cfg.head_dim)
        v = L.dense(p["wv"], xin, cfg).reshape(
            b, 1, cfg.n_kv_heads, cfg.head_dim)
        q = L.apply_rope(q, pos[None, None], cfg.rope_theta)
        k = L.apply_rope(k, pos[None, None], cfg.rope_theta)
        k_c = lax.dynamic_update_slice_in_dim(          # the clamping bug
            k_c, T._maybe_quant_kv(k, cfg), pos, 1)
        v_c = lax.dynamic_update_slice_in_dim(
            v_c, T._maybe_quant_kv(v, cfg), pos, 1)
        a = L.decode_attention(q, k_c, v_c, pos + 1, cfg=cfg,
                               kv_posit=cfg.kv_posit)
        h = h + L.dense(p["wo"], a.reshape(b, 1, -1), cfg)
        hh = L.rms_norm(lp["ln2"], h, cfg)
        return h + L.mlp(lp["mlp"], hh, cfg), (k_c, v_c)

    x, (k_new, v_new) = lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rms_norm(params["final_norm"], x, cfg)
    logits = x[:, 0, :] @ T._unembed_weight(params, cfg).astype(x.dtype)
    return logits.astype(jnp.float32), dict(cache, k=k_new, v=v_new,
                                            len=pos + 1)


# ---------------------------------------------------------------------------
# the clamp-overwrite regression (dense, MLA, sliding-window lanes)
# ---------------------------------------------------------------------------

def test_dense_decode_no_clamp_overwrite_and_differs_from_broken():
    """Prefill s tokens, decode 3: slot s-1 must stay untouched and the
    logits must differ from the seed's clamp-overwrite behaviour."""
    cfg = _dense_cfg()
    params = _params(cfg)
    rng = np.random.default_rng(0)
    b, s = 2, 8
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (b, s)), jnp.int32)

    cache, logits = T.prefill(params, tokens, cfg, max_len=s + 8)
    slot = np.asarray(cache["k"][:, :, s - 1])
    assert np.abs(slot).sum() > 0                    # a real prompt key

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    fixed_logits = []
    for _ in range(3):
        logits, cache = T.decode_step(params, cache, tok, cfg)
        fixed_logits.append(np.asarray(logits))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)

    # the last prompt KV slot is untouched; decode landed in headroom
    np.testing.assert_array_equal(np.asarray(cache["k"][:, :, s - 1]), slot)
    assert np.abs(np.asarray(cache["k"][:, :, s:s + 3])).sum() > 0
    assert int(cache["len"]) == s + 3

    # broken reference: prompt-sized cache + clamping writes (the seed).
    # Feed it the SAME token sequence; by the second step its logits must
    # diverge — it has been overwriting slot s-1.
    bcache, blogits = T.prefill(params, tokens, cfg)     # no headroom
    bcache = {"k": bcache["k"], "v": bcache["v"], "len": bcache["len"]}
    broken_logits = []
    toks = [jnp.argmax(blogits, -1).astype(jnp.int32)]
    for i in range(3):
        lg, bcache = _seed_decode_step(params, bcache, toks[-1], cfg)
        broken_logits.append(np.asarray(lg))
        toks.append(jnp.argmax(lg, -1).astype(jnp.int32))
    assert not (np.asarray(bcache["k"][:, :, s - 1]) == slot).all(), \
        "broken reference should have clobbered slot s-1"
    assert np.abs(broken_logits[-1] - fixed_logits[-1]).max() > 1e-4, \
        "fixed decode should differ from the clamp-overwrite behaviour"


def test_mla_decode_no_clamp_overwrite():
    cfg = _mla_cfg()
    params = _params(cfg)
    rng = np.random.default_rng(1)
    b, s = 2, 8
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (b, s)), jnp.int32)
    cache, logits = T.prefill(params, tokens, cfg, max_len=s + 8)
    slot_c = np.asarray(cache["c_kv"][:, :, s - 1])
    slot_r = np.asarray(cache["k_rope"][:, :, s - 1])
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = T.decode_step(params, cache, tok, cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(cache["c_kv"][:, :, s - 1]), slot_c)
    np.testing.assert_array_equal(
        np.asarray(cache["k_rope"][:, :, s - 1]), slot_r)
    assert np.abs(np.asarray(cache["c_kv"][:, :, s:s + 3])).sum() > 0
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "minicpm3-4b",
                                  "whisper-tiny"])
def test_decode_past_capacity_raises(arch):
    """Out-of-capacity decode writes must raise, not clamp-overwrite."""
    cfg = configs.get_config(arch).reduced(compute_dtype="float32")
    fam = get_family(cfg)
    params = _params(cfg, seed=2)
    rng = np.random.default_rng(2)
    b, cap = 2, 4
    cache = fam.init_cache(cfg, b, cap)
    tok = jnp.asarray(rng.integers(1, cfg.vocab, (b,)), jnp.int32)
    for _ in range(cap):
        logits, cache = fam.decode_step(params, cache, tok, cfg)
    with pytest.raises(ValueError, match="capacity"):
        fam.decode_step(params, cache, tok, cfg)


def test_traced_out_of_capacity_write_drops_not_clamps():
    """Under jit the guard cannot raise; it must DROP the write (never
    clamp onto the last slot)."""
    cfg = _dense_cfg()
    params = _params(cfg, seed=3)
    rng = np.random.default_rng(3)
    b, cap = 1, 4
    fam = get_family(cfg)
    cache = fam.init_cache(cfg, b, cap)
    tok = jnp.asarray(rng.integers(1, cfg.vocab, (b,)), jnp.int32)
    step = jax.jit(lambda c, t: fam.decode_step(params, c, t, cfg))
    for _ in range(cap):
        logits, cache = step(cache, tok)
    last = np.asarray(cache["k"][:, :, cap - 1])
    logits, cache = step(cache, tok)              # past capacity, traced
    np.testing.assert_array_equal(np.asarray(cache["k"][:, :, cap - 1]),
                                  last)


# ---------------------------------------------------------------------------
# sliding-window ring buffer: golden vs full-length reference
# ---------------------------------------------------------------------------

def test_sliding_window_ring_matches_full_length_reference():
    """Ring-buffer cache (capacity = window, pos % window writes,
    rotation-aware masks) must reproduce a full-length reference cache
    bit-for-tolerance across >2 wraparounds, including a prompt longer
    than the window (prefill ring packing)."""
    cfg = _dense_cfg(sliding_window=8, attn_chunk_kv=8)
    params = _params(cfg, seed=4)
    rng = np.random.default_rng(4)
    b, s, ml = 2, 12, 40
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (b, s)), jnp.int32)

    def gen(window_ring):
        cache, logits = T.prefill(params, tokens, cfg, max_len=ml,
                                  window_ring=window_ring)
        step = jax.jit(lambda c, t: T.decode_step(params, c, t, cfg))
        outs = [np.asarray(logits)]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(20):
            logits, cache = step(cache, tok)
            outs.append(np.asarray(logits))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return outs, cache

    ring_outs, ring_cache = gen(True)
    full_outs, full_cache = gen(False)
    assert ring_cache["k"].shape[2] == cfg.sliding_window   # ring-sized
    assert full_cache["k"].shape[2] == ml                   # reference
    for i, (a, bb) in enumerate(zip(ring_outs, full_outs)):
        np.testing.assert_allclose(a, bb, rtol=1e-4, atol=1e-4,
                                   err_msg=f"step {i}")


def test_hymba_decode_no_clamp_overwrite():
    """Hymba's hybrid cache used raw ``dynamic_update_slice_in_dim`` for
    its SWA ring writes (the PVU001 bug class): once ``pos % window``
    computed a slot past the clamp bound the write would silently pile
    onto the last ring slot.  Pin the guarded semantics on both lanes:
    every decode step must touch exactly ring slot ``pos % window`` (no
    clamp pile-up), and the global layer's last prompt slot must survive
    decode into headroom, mirroring the dense clamp test above."""
    cfg = configs.get_config("hymba-1.5b").reduced(
        compute_dtype="float32", sliding_window=4)
    H = get_family(cfg)
    params = _params(cfg, seed=3)
    rng = np.random.default_rng(3)
    b, s, steps = 2, 6, 5
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (b, s)), jnp.int32)

    cache, logits = H.prefill(params, tokens, cfg, max_len=s + 8)
    w = cache["k_swa"].shape[2]
    assert w == cfg.sliding_window                       # ring-sized
    # global_layers=(0,) -> layer 1 is the SWA ring lane
    gslot = np.asarray(cache["k_glb"][0][:, s - 1])
    assert np.abs(gslot).sum() > 0                       # a real prompt key

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(steps):
        pos = int(cache["len"])
        before = np.asarray(cache["k_swa"][1])
        logits, cache = H.decode_step(params, cache, tok, cfg)
        after = np.asarray(cache["k_swa"][1])
        for t in range(w):
            if t == pos % w:
                assert not (after[:, t] == before[:, t]).all(), \
                    f"pos {pos}: ring slot {t} should have been written"
            else:
                np.testing.assert_array_equal(
                    after[:, t], before[:, t],
                    err_msg=f"pos {pos}: ring slot {t} clobbered "
                            f"(clamp pile-up?)")
        tok = jnp.argmax(logits, -1).astype(jnp.int32)

    # global lane: last prompt slot untouched, decode landed in headroom
    np.testing.assert_array_equal(np.asarray(cache["k_glb"][0][:, s - 1]),
                                  gslot)
    assert np.abs(np.asarray(cache["k_glb"][0][:, s:s + steps])).sum() > 0
    assert int(cache["len"]) == s + steps


# ---------------------------------------------------------------------------
# engine: one-scan decode, ragged batching, capacity enforcement
# ---------------------------------------------------------------------------

def test_scan_decode_64_steps_matches_stepwise_in_one_compiled_call():
    """>= 64 scan-decoded tokens must equal the per-step jitted loop,
    with the whole scan generation running as ONE compiled dispatch
    while the loop dispatches once per token."""
    cfg = _dense_cfg()
    params = _params(cfg, seed=5)
    rng = np.random.default_rng(5)
    prompts = rng.integers(1, cfg.vocab, (2, 8))

    def counted(fn, counter):
        def wrapped(*a):
            counter["n"] += 1
            return fn(*a)
        return wrapped

    e_scan = Engine(cfg, params, max_len=80, seed=0)
    scan_calls = {"n": 0}
    e_scan._decode_jit[64] = counted(e_scan._decode_fn(64), scan_calls)
    r_scan = e_scan.generate(prompts, 64)

    e_step = Engine(cfg, params, max_len=80, seed=0)
    step_calls = {"n": 0}
    fam = get_family(cfg)
    e_step._decode_jit["step"] = counted(
        jax.jit(lambda p, c, t: fam.decode_step(p, c, t, cfg)), step_calls)
    r_step = e_step.generate_stepwise(prompts, 64)

    assert (r_scan.tokens == r_step.tokens).all()
    assert r_scan.tokens.shape == (2, 64)
    # scan: the full generation is one compiled call; loop: one dispatch
    # per generated token
    assert scan_calls["n"] == 1, scan_calls
    assert step_calls["n"] == 63, step_calls


def test_decode_chunk_concatenation_matches_one_scan():
    """Two 4-step decode chunks seeded from the prefill token must emit
    exactly what generate's single 9-token scan emits — the equivalence
    the continuous-batching scheduler is built on."""
    cfg = _dense_cfg()
    params = _params(cfg, seed=11)
    rng = np.random.default_rng(11)
    prompts = rng.integers(1, cfg.vocab, (2, 6))

    eng = Engine(cfg, params, max_len=24, seed=0)
    ref = eng.generate(prompts, 9).tokens                # tok0 + 8 decoded

    eng2 = Engine(cfg, params, max_len=24, seed=0)
    cache, logits, _ = eng2.prefill(prompts)
    tok0 = np.asarray(jnp.argmax(logits, -1), np.int32)
    cache, c1 = eng2.decode_chunk(cache, tok0, 4)
    cache, c2 = eng2.decode_chunk(cache, np.asarray(c1)[:, -1], 4)
    got = np.concatenate([tok0[:, None], np.asarray(c1),
                          np.asarray(c2)], axis=1)
    np.testing.assert_array_equal(got, ref)


def test_decode_chunk_active_mask_freezes_inactive_lens():
    """Inactive rows ride along in the batch but their ``lens`` metadata
    must not advance (otherwise empty slots pin the compaction frontier)."""
    cfg = _dense_cfg()
    params = _params(cfg, seed=12)
    rng = np.random.default_rng(12)
    prompts = rng.integers(1, cfg.vocab, (2, 5))
    eng = Engine(cfg, params, max_len=24, seed=0)
    cache, logits, _ = eng.prefill(prompts)
    tok0 = np.asarray(jnp.argmax(logits, -1), np.int32)
    cache, _ = eng.decode_chunk(cache, tok0, 3,
                                active=np.array([True, False]))
    assert np.asarray(cache["lens"]).tolist() == [8, 5]
    assert int(cache["len"]) == 8        # the shared frontier still moves


def test_decode_chunk_refuses_to_run_past_max_len():
    """A chunk that would push the frontier past max_len must raise —
    the traced in-chunk writes would otherwise be silently dropped."""
    cfg = _dense_cfg()
    params = _params(cfg, seed=13)
    rng = np.random.default_rng(13)
    eng = Engine(cfg, params, max_len=12, seed=0)
    cache, logits, _ = eng.prefill(rng.integers(1, cfg.vocab, (1, 6)))
    tok0 = np.asarray(jnp.argmax(logits, -1), np.int32)
    cache, _ = eng.decode_chunk(cache, tok0, 6)       # 6 + 6 = 12 fits
    with pytest.raises(ValueError, match="max_len"):
        eng.decode_chunk(cache, tok0, 1)              # 13 > 12


def test_ragged_batch_matches_singleton_generations():
    """Unequal-length prompts share one batch (left-padding + masks) and
    generate the same tokens as each prompt alone."""
    cfg = _dense_cfg()
    params = _params(cfg, seed=6)
    rng = np.random.default_rng(6)
    p1 = rng.integers(1, cfg.vocab, (5,)).tolist()
    p2 = rng.integers(1, cfg.vocab, (9,)).tolist()

    eng = Engine(cfg, params, max_len=32, seed=0)
    batched = eng.generate([p1, p2], 8)
    assert batched.prompt_lens.tolist() == [5, 9]

    solo1 = Engine(cfg, params, max_len=32, seed=0).generate([p1], 8)
    solo2 = Engine(cfg, params, max_len=32, seed=0).generate([p2], 8)
    np.testing.assert_allclose(batched.prefill_logits[0],
                               solo1.prefill_logits[0],
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(batched.prefill_logits[1],
                               solo2.prefill_logits[0],
                               rtol=5e-4, atol=5e-4)
    assert (batched.tokens[0] == solo1.tokens[0]).all()
    assert (batched.tokens[1] == solo2.tokens[0]).all()


def test_ragged_rejected_outside_transformer_family():
    cfg = configs.get_config("rwkv6-7b").reduced(compute_dtype="float32")
    params = _params(cfg, seed=7)
    eng = Engine(cfg, params, max_len=16)
    with pytest.raises(ValueError, match="ragged"):
        eng.generate([[1, 2], [3, 4, 5]], 2)


def test_engine_refuses_requests_beyond_max_len():
    cfg = _dense_cfg()
    params = _params(cfg, seed=8)
    eng = Engine(cfg, params, max_len=12)
    rng = np.random.default_rng(8)
    prompts = rng.integers(1, cfg.vocab, (1, 8))
    eng.generate(prompts, 5)                        # 8 + 5 - 1 = 12 fits
    with pytest.raises(ValueError, match="max_len"):
        eng.generate(prompts, 6)                    # 13 > 12
    with pytest.raises(ValueError, match="max_len"):
        eng.generate_stepwise(prompts, 6)           # same guard, both paths


@pytest.mark.parametrize("arch,kw", [
    ("whisper-tiny", "frames"),
    ("internvl2-1b", "visual"),
])
def test_engine_routes_encoder_state(arch, kw):
    """frames/visual must flow through prefill while decode runs off the
    cached encoder state — the old serve.py dropped them."""
    cfg = configs.get_config(arch).reduced(compute_dtype="float32")
    params = _params(cfg, seed=9)
    rng = np.random.default_rng(9)
    b = 2
    if kw == "frames":
        aux = jnp.asarray(rng.standard_normal(
            (b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    else:
        aux = jnp.asarray(rng.standard_normal(
            (b, cfg.n_visual_tokens, cfg.d_model)), jnp.float32)
    eng = Engine(cfg, params, max_len=24)
    res = eng.generate(rng.integers(1, cfg.vocab, (b, 8)), 8, **{kw: aux})
    assert res.tokens.shape == (b, 8)
    assert np.isfinite(res.prefill_logits).all()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["hymba-1.5b", "rwkv6-7b",
                                  "minicpm3-4b", "gemma-7b"])
def test_engine_scan_matches_stepwise_all_families(arch):
    cfg = configs.get_config(arch).reduced(compute_dtype="float32")
    params = _params(cfg, seed=10)
    rng = np.random.default_rng(10)
    prompts = rng.integers(1, cfg.vocab, (2, 8))
    r1 = Engine(cfg, params, max_len=48, seed=1).generate(prompts, 16)
    r2 = Engine(cfg, params, max_len=48, seed=1).generate_stepwise(
        prompts, 16)
    assert (r1.tokens == r2.tokens).all()
