"""Tensor-parallel serving on a real (8 fake-device) host mesh, via
subprocess so the forced-device env var never leaks into other tests.

These tests *execute* the sharded serving stack (not just compile):
the mesh engine — weights by the ``runtime/sharding.py`` rule table,
the paged KV arena head-sharded over 'model' — must reproduce the
single-device scheduler token for token AND step for step, including
prefix-cache hits, a deadline-driven preemption restart, and the fused
Pallas decode kernel, with the arena sanitizer armed and leak-free
throughout.  The byte ledger also checks the point of the exercise:
each device holds ~1/mp of the arena content.
"""
import json
import os
import subprocess
import sys

import pytest

# 8-fake-device subprocess runs (compile-heavy): full lane only
pytestmark = pytest.mark.slow

_SCRIPT_IDENTITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import numpy as np
import jax

from repro import configs
from repro.compress.kvcache import cache_report
from repro.launch.mesh import make_host_mesh
from repro.models import get_family
from repro.runtime.engine import Engine
from repro.runtime.scheduler import Scheduler

cfg = configs.get_config("phi3-medium-14b").reduced(
    compute_dtype="float32")
cfg = dataclasses.replace(cfg, n_heads=4, n_kv_heads=4)
params = get_family(cfg).init_params(jax.random.PRNGKey(0), cfg)

rng = np.random.default_rng(0)
prompts = [list(map(int, rng.integers(1, cfg.vocab, size=n)))
           for n in (12, 9, 17, 5, 14, 11)]
prompts[3] = prompts[2][:12] + prompts[3]     # shared prefix pair

def run(mesh):
    eng = Engine(cfg, params, max_len=96, paged=True, block_size=8,
                 n_blocks=40, sanitize=True, mesh=mesh)
    sched = Scheduler(eng, n_slots=3, chunk_size=4, prefix_cache=True)
    for p in prompts:
        sched.submit(p, 12)
    out = sched.run(max_rounds=500)
    toks = {str(r): out[r].tokens.tolist() for r in sorted(out)}
    fin = {str(r): out[r].finished_step for r in sorted(out)}
    return toks, fin, sched

toks1, fin1, s1 = run(None)
toks2, fin2, s2 = run(make_host_mesh(4))
rep1, rep2 = cache_report(s1.cache), cache_report(s2.cache)
print(json.dumps({
    "tokens_match": toks1 == toks2,
    "schedule_match": fin1 == fin2,
    "prefix_hits_single": s1.stats["prefix_hits"],
    "prefix_hits_sharded": s2.stats["prefix_hits"],
    "n_leaked": s2.stats["n_leaked"],
    "arena_spec": str(s2.cache["k"].sharding.spec),
    "bytes": rep2["bytes"],
    "per_device_single": rep1["per_device_bytes"],
    "per_device_sharded": rep2["per_device_bytes"],
    "wall_p50_ms": s2.stats["step_wall_p50_ms"],
    "wall_p99_ms": s2.stats["step_wall_p99_ms"],
}))
"""

_SCRIPT_PREEMPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, warnings
import numpy as np
import jax

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import get_family
from repro.runtime.engine import Engine
from repro.runtime.scheduler import Scheduler

# satellite: a non-dividing tensor-parallel degree rounds down + warns
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    m3 = make_host_mesh(3)
mesh3_ok = (dict(m3.shape) == {"data": 4, "model": 2}
            and any("rounding down" in str(x.message) for x in w))

cfg = configs.get_config("phi3-medium-14b").reduced(
    compute_dtype="float32")
cfg = dataclasses.replace(cfg, n_heads=4, n_kv_heads=4)
params = get_family(cfg).init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(1)
prompts = [list(map(int, rng.integers(1, cfg.vocab, size=n)))
           for n in (10, 8, 12)]

def run(mesh, kernel=None):
    # pool sized so the deadline head cannot be admitted without
    # preempting a resident best-effort row
    eng = Engine(cfg, params, max_len=64, paged=True, block_size=8,
                 n_blocks=10, sanitize=True, mesh=mesh,
                 decode_kernel=kernel)
    sched = Scheduler(eng, n_slots=3, chunk_size=4, chunked_prefill=True)
    sched.submit(prompts[0], 16)              # best-effort, long
    sched.submit(prompts[1], 16)              # best-effort, long
    for _ in range(2):
        sched.step()
    sched.submit(prompts[2], 8, deadline=20)  # EDF head, pool is full
    out = sched.run(max_rounds=500)
    toks = {str(r): out[r].tokens.tolist() for r in sorted(out)}
    fin = {str(r): out[r].finished_step for r in sorted(out)}
    return toks, fin, sched

t1, f1, s1 = run(None)
t2, f2, s2 = run(make_host_mesh(4))
t3, f3, s3 = run(make_host_mesh(4), kernel="fused")
print(json.dumps({
    "mesh3_ok": mesh3_ok,
    "n_preempted_single": s1.n_preempted,
    "n_preempted_sharded": s2.n_preempted,
    "tokens_match": t1 == t2,
    "schedule_match": f1 == f2,
    "fused_tokens_match": t1 == t3,
    "fused_schedule_match": f1 == f3,
    "n_leaked": s2.stats["n_leaked"] + s3.stats["n_leaked"],
}))
"""


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_serving_matches_single_device():
    r = _run(_SCRIPT_IDENTITY)
    assert r["tokens_match"], r
    assert r["schedule_match"], r
    # prefix dedup must survive sharding, hit for hit
    assert r["prefix_hits_sharded"] == r["prefix_hits_single"] > 0, r
    assert r["n_leaked"] == 0, r
    # the arena is head-sharded, and each device holds ~1/4 of it
    assert "model" in r["arena_spec"], r
    assert r["per_device_single"] == r["bytes"], r
    assert r["per_device_sharded"] < r["bytes"] / 2, r
    assert r["wall_p99_ms"] >= r["wall_p50_ms"] > 0, r


def test_sharded_preemption_and_fused_kernel_match():
    r = _run(_SCRIPT_PREEMPT)
    assert r["mesh3_ok"], r
    # the deadline request forces a restart in BOTH runs, identically
    assert r["n_preempted_single"] > 0, r
    assert r["n_preempted_sharded"] == r["n_preempted_single"], r
    assert r["tokens_match"] and r["schedule_match"], r
    assert r["fused_tokens_match"] and r["fused_schedule_match"], r
    assert r["n_leaked"] == 0, r
