"""positcheck (``repro.analysis``) — the static analyzer that makes our
shipped bug classes unwritable.

Three layers of pinning:

* Per-rule fixtures — each PVU rule fires on a minimal bad exemplar
  (modelled on the real bug it encodes), stays silent on the idiomatic
  good version, and is suppressed by a per-line
  ``# positcheck: disable=PVUxxx`` waiver.
* The PR 3 / hymba regression in miniature — reintroducing the raw
  ``lax.dynamic_update_slice_in_dim`` ring write this PR removed from
  ``models/hymba.py`` is caught by PVU001.
* Repo integration — ``python -m repro.analysis src/`` exits 0 on the
  repo (zero non-waived findings), which is exactly the CI lint-lane
  contract.

The analyzer is stdlib-only, so none of this needs jax.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from repro.analysis import ALL_RULES, run_paths, rule_by_id

REPO = Path(__file__).resolve().parent.parent


def _run(tmp_path, code, filename="mod.py"):
    p = tmp_path / filename
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code))
    active, waived, errors = run_paths([p], ALL_RULES)
    assert not errors, errors
    return active, waived


def _ids(findings):
    return [f.rule_id for f in findings]


# ---------------------------------------------------------------------------
# PVU001 — raw dynamic_update_slice* cache writes (the clamp bug class)
# ---------------------------------------------------------------------------

# the exact shape of the hymba SWA ring write this PR fixed: a raw
# in_dim update whose start would CLAMP (not drop) when out of range
BAD_HYMBA_RING = """
    from jax import lax

    def decode_step(k_swa, upd, pos, window):
        slot = lax.rem(pos, window)
        kc = lax.dynamic_update_slice_in_dim(k_swa, upd, slot, 1)
        return kc
"""


def test_pvu001_fires_on_reintroduced_hymba_ring_write(tmp_path):
    active, _ = _run(tmp_path, BAD_HYMBA_RING)
    assert _ids(active) == ["PVU001"]
    (f,) = active
    assert f.line == 6  # the dynamic_update_slice_in_dim line
    assert "clamps" in f.message
    assert "guarded_cache_update" in f.hint


def test_pvu001_fires_on_multiarg_dynamic_update_slice(tmp_path):
    active, _ = _run(tmp_path, """
        import jax.lax as lax

        def graft(leaf, upd, row):
            return lax.dynamic_update_slice(leaf, upd, (0, row, 0))
    """)
    assert _ids(active) == ["PVU001"]


def test_pvu001_silent_inside_guarded_wrapper_and_on_guarded_calls(tmp_path):
    active, _ = _run(tmp_path, """
        from jax import lax
        import jax.numpy as jnp

        def guarded_cache_update(arr, upd, idx, axis):
            new = lax.dynamic_update_slice_in_dim(arr, upd, idx, axis)
            return jnp.where(idx < arr.shape[axis], new, arr)

        def decode_step(L, k_swa, upd, slot):
            return L.guarded_cache_update(k_swa, upd, slot, 1)
    """)
    assert active == []


def test_pvu001_waiver_suppresses_with_audit_trail(tmp_path):
    active, waived = _run(tmp_path, """
        from jax import lax

        def graft(leaf, upd, row):
            # row < n_slots by construction; starts cannot clamp
            return lax.dynamic_update_slice(leaf, upd, (0, row, 0))  # positcheck: disable=PVU001
    """)
    assert active == []
    assert _ids(waived) == ["PVU001"]


# ---------------------------------------------------------------------------
# PVU002 — dequant -> f32 -> requant round-trips
# ---------------------------------------------------------------------------

ROUND_TRIP = """
    def scale(cache, s):
        return quantize(dequantize(cache) * s)
"""


def test_pvu002_fires_on_round_trip_outside_internals(tmp_path):
    active, _ = _run(tmp_path, ROUND_TRIP)
    assert _ids(active) == ["PVU002"]
    assert active[0].severity == "warning"
    assert "vadd" in active[0].hint  # points at the fused kernels


def test_pvu002_silent_in_kernels_and_compress(tmp_path):
    for where in ("kernels/posit_ew.py", "compress/kvcache.py"):
        active, _ = _run(tmp_path, ROUND_TRIP, filename=where)
        assert active == [], where


def test_pvu002_silent_on_posit_domain_compute(tmp_path):
    active, _ = _run(tmp_path, """
        def scale(cache, s, ops):
            return ops.vmul(cache, s)

        def encode(x):
            return f32_to_posit(x, 8, 0)
    """)
    assert active == []


def test_pvu002_waiver(tmp_path):
    active, waived = _run(tmp_path, """
        def slow_reference(cache, s):
            return quantize(dequantize(cache) * s)  # positcheck: disable=PVU002
    """)
    assert active == [] and _ids(waived) == ["PVU002"]


# ---------------------------------------------------------------------------
# PVU003 — dtype sniffing on cache leaves
# ---------------------------------------------------------------------------

def test_pvu003_fires_on_issubdtype_and_dtype_compare(tmp_path):
    active, _ = _run(tmp_path, """
        import jax.numpy as jnp

        def is_patterns(cache):
            return jnp.issubdtype(cache["k"].dtype, jnp.unsignedinteger)

        def is_quantized(kv_cache):
            return kv_cache["v"].dtype == jnp.uint8
    """)
    assert _ids(active) == ["PVU003", "PVU003"]
    assert "CONTENT_LEAVES" in active[0].hint


def test_pvu003_silent_on_schema_and_weight_sniffing(tmp_path):
    active, _ = _run(tmp_path, """
        import jax.numpy as jnp

        def classify(key, CONTENT_LEAVES):
            return key in CONTENT_LEAVES

        def maybe_dequant(w):
            # weights are not cache leaves: sniffing is fine here
            if jnp.issubdtype(w.dtype, jnp.unsignedinteger):
                return w
            return w
    """)
    assert active == []


def test_pvu003_silent_inside_kvcache_itself(tmp_path):
    active, _ = _run(tmp_path, """
        import jax.numpy as jnp

        def leaf_kind(cache, k):
            return jnp.issubdtype(cache[k].dtype, jnp.unsignedinteger)
    """, filename="compress/kvcache.py")
    assert active == []


def test_pvu003_waiver(tmp_path):
    active, waived = _run(tmp_path, """
        import jax.numpy as jnp

        def probe(cache):
            return jnp.issubdtype(cache["k"].dtype, jnp.floating)  # positcheck: disable=PVU003
    """)
    assert active == [] and _ids(waived) == ["PVU003"]


# ---------------------------------------------------------------------------
# PVU004 — python if/assert on traced values
# ---------------------------------------------------------------------------

def test_pvu004_fires_in_jit_decorated_function(tmp_path):
    active, _ = _run(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    assert _ids(active) == ["PVU004"]
    assert "trace time" in active[0].message


def test_pvu004_fires_in_scan_body_and_jit_wrapping(tmp_path):
    active, _ = _run(tmp_path, """
        import jax
        from jax import lax

        def step(carry, x):
            assert x.sum() > 0
            return carry, x

        def outer(xs):
            return lax.scan(step, 0, xs)

        def g(y):
            if y == 1:
                return y
            return y + 1

        g_fast = jax.jit(g)
    """)
    assert _ids(active) == ["PVU004", "PVU004"]


def test_pvu004_silent_on_static_predicates(tmp_path):
    active, _ = _run(tmp_path, """
        import jax

        @jax.jit
        def f(x, active=None, cfg=None):
            if x.shape[0] > 2:          # shapes are static under trace
                x = x * 2
            if active is None:          # identity checks are host-side
                x = x + 1
            if cfg.sliding_window:      # cfg is static config
                x = x - 1
            assert isinstance(x, object)
            return x

        def not_traced(x):
            if x > 0:                   # plain python function: fine
                return x
            return -x
    """)
    assert active == []


def test_pvu004_waiver(tmp_path):
    active, waived = _run(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            if x > 0:  # positcheck: disable=PVU004
                return x
            return -x
    """)
    assert active == [] and _ids(waived) == ["PVU004"]


# ---------------------------------------------------------------------------
# PVU005 — BlockPool private state outside the allocator
# ---------------------------------------------------------------------------

def test_pvu005_fires_on_private_allocator_state(tmp_path):
    active, _ = _run(tmp_path, """
        def steal(pool, bid):
            pool._free.append(bid)
            del pool._ref[bid]
    """)
    assert _ids(active) == ["PVU005", "PVU005"]
    assert "share/release" in active[0].message


def test_pvu005_silent_on_refcount_api_and_in_kvcache(tmp_path):
    active, _ = _run(tmp_path, """
        def borrow(pool, ids):
            pool.share(ids)
            return pool.refcount(ids[0]), pool.n_free

        def retire(pool, ids):
            return pool.release(ids)
    """)
    assert active == []
    active, _ = _run(tmp_path, """
        class BlockPool:
            def alloc(self, n):
                return [self._free.pop() for _ in range(n)]
    """, filename="compress/kvcache.py")
    assert active == []


def test_pvu005_waiver(tmp_path):
    active, waived = _run(tmp_path, """
        def debug_dump(pool):
            return list(pool._ref)  # positcheck: disable=PVU005
    """)
    assert active == [] and _ids(waived) == ["PVU005"]


# ---------------------------------------------------------------------------
# PVU006 — jit specialization on prompt-length-like static args
# ---------------------------------------------------------------------------

# the PR 8 deletion in miniature: the old engine kept one compiled
# prefill per prompt length by making plen a static arg
BAD_PLEN_JIT = """
    import jax
    import functools

    def prefill(params, toks, plen):
        return toks[:plen]

    fast = jax.jit(prefill, static_argnames=("plen",))
    also = functools.partial(jax.jit, static_argnames=["prompt_len"])
"""


def test_pvu006_fires_on_plen_static_args(tmp_path):
    active, _ = _run(tmp_path, BAD_PLEN_JIT)
    assert _ids(active) == ["PVU006", "PVU006"]
    assert "per prompt length" in active[0].message
    assert "mixed_step" in active[0].hint


def test_pvu006_fires_on_static_argnums_resolved_to_plen(tmp_path):
    active, _ = _run(tmp_path, """
        import jax

        def prefill(params, toks, seq_len):
            return toks[:seq_len]

        fast = jax.jit(prefill, static_argnums=(2,))
    """)
    assert _ids(active) == ["PVU006"]
    assert "seq_len" in active[0].message


def test_pvu006_silent_on_capacity_and_config_statics(tmp_path):
    # the repo's real static args: config objects, block geometry,
    # window/ring flags, capacity bounds — none are per-request lengths
    active, _ = _run(tmp_path, """
        import jax
        import functools

        @functools.partial(jax.jit, static_argnames=("cfg", "block", "interpret"))
        def kernel(x, cfg, block, interpret):
            return x

        def pack(arena, tables, window, src_ring):
            return arena

        fast = jax.jit(pack, static_argnames=("window", "src_ring"))
        cap = jax.jit(lambda x, max_len: x, static_argnames=("max_len",))
    """)
    assert active == []


def test_pvu006_silent_inside_engine(tmp_path):
    active, _ = _run(tmp_path, BAD_PLEN_JIT,
                     filename="runtime/engine.py")
    assert active == []


def test_pvu006_waiver(tmp_path):
    active, waived = _run(tmp_path, """
        import jax

        def f(x, plen):
            return x[:plen]

        g = jax.jit(f, static_argnames=("plen",))  # positcheck: disable=PVU006
    """)
    assert active == [] and _ids(waived) == ["PVU006"]


# ---------------------------------------------------------------------------
# PVU007 — cache/arena placed or created without sharding machinery
# ---------------------------------------------------------------------------

# the implicit-replication class the sharded arena PR exists to prevent:
# a bare device_put of the cache lands a full copy on EVERY device
BAD_BARE_DEVICE_PUT = """
    import jax

    def adopt(cache):
        return jax.device_put(cache)
"""

BAD_FRESH_ARENA = """
    import jax.numpy as jnp

    def grow_pool(cfg, nb, bs):
        arena = jnp.zeros((cfg.n_layers, nb, bs, 4, 8), jnp.float32)
        return arena
"""


def test_pvu007_fires_on_bare_device_put_in_runtime(tmp_path):
    active, _ = _run(tmp_path, BAD_BARE_DEVICE_PUT,
                     filename="runtime/adopt.py")
    assert _ids(active) == ["PVU007"]


def test_pvu007_fires_on_fresh_arena_outside_init(tmp_path):
    active, _ = _run(tmp_path, BAD_FRESH_ARENA,
                     filename="models/pool.py")
    assert _ids(active) == ["PVU007"]


def test_pvu007_silent_outside_runtime_and_models(tmp_path):
    # kernels/benchmarks/tests build throwaway arenas on purpose
    active, _ = _run(tmp_path, BAD_BARE_DEVICE_PUT,
                     filename="kernels/scratch.py")
    assert active == []


def test_pvu007_silent_on_sharded_placement_and_init(tmp_path):
    active, _ = _run(tmp_path, """
        import jax
        import jax.numpy as jnp
        from repro.runtime import sharding as shd

        def shard_cache(cache, mesh, cfg):
            return jax.device_put(
                cache, shd.paged_cache_shardings(cache, mesh, cfg))

        def init_paged_cache(cfg, nb, bs):
            # sanctioned constructor: the engine places its output
            arena = jnp.zeros((cfg.n_layers, nb, bs, 4, 8), jnp.float32)
            return {"k": arena}

        def resize(cache, mesh):
            # creating next to a with_sharding_constraint is fine too
            arena = jnp.zeros_like(cache["k"])
            return jax.lax.with_sharding_constraint(arena, None)
    """, filename="runtime/engine2.py")
    assert active == []


def test_pvu007_waiver(tmp_path):
    active, waived = _run(tmp_path, """
        import jax

        def debug_snapshot(cache):
            # host-side debugging copy; never enters the serving path
            return jax.device_put(cache)  # positcheck: disable=PVU007
    """, filename="runtime/debug.py")
    assert active == [] and _ids(waived) == ["PVU007"]


# ---------------------------------------------------------------------------
# framework behaviour
# ---------------------------------------------------------------------------

def test_disable_all_waives_every_rule_on_the_line(tmp_path):
    active, waived = _run(tmp_path, """
        from jax import lax

        def graft(leaf, upd, row):
            return lax.dynamic_update_slice(leaf, upd, (0, row))  # positcheck: disable=all
    """)
    assert active == [] and _ids(waived) == ["PVU001"]


def test_waiver_on_other_line_does_not_suppress(tmp_path):
    active, _ = _run(tmp_path, """
        from jax import lax
        # positcheck: disable=PVU001

        def graft(leaf, upd, row):
            return lax.dynamic_update_slice(leaf, upd, (0, row))
    """)
    assert _ids(active) == ["PVU001"]


def test_rule_registry_is_complete():
    ids = [r.id for r in ALL_RULES]
    assert ids == ["PVU001", "PVU002", "PVU003", "PVU004", "PVU005",
                   "PVU006", "PVU007"]
    for rid in ids:
        r = rule_by_id(rid)
        assert r.severity in ("error", "warning")
        assert r.hint and r.title


def test_syntax_error_is_reported_not_fatal(tmp_path):
    (tmp_path / "broken.py").write_text("def f(:\n")
    active, waived, errors = run_paths([tmp_path], ALL_RULES)
    assert active == [] and waived == []
    assert len(errors) == 1 and "broken.py" in errors[0]


# ---------------------------------------------------------------------------
# repo integration: the CI contract
# ---------------------------------------------------------------------------

def _analysis_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return env


def test_repo_src_is_positcheck_clean():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src"],
        cwd=REPO, env=_analysis_env(), capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 finding(s)" in proc.stdout


def test_cli_exits_nonzero_on_findings(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(BAD_HYMBA_RING))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(bad)],
        cwd=REPO, env=_analysis_env(), capture_output=True, text=True)
    assert proc.returncode == 1
    assert "PVU001" in proc.stdout


def test_cli_list_rules():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--list-rules"],
        cwd=REPO, env=_analysis_env(), capture_output=True, text=True)
    assert proc.returncode == 0
    for rid in ("PVU001", "PVU002", "PVU003", "PVU004", "PVU005",
                "PVU006", "PVU007"):
        assert rid in proc.stdout
