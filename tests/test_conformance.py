"""Exhaustive posit8 conformance suite: kernels vs the SoftPosit golden.

The paper validates the PVU per-op against SoftPosit (its §VI table:
add/sub/mul/dot 100 %, div 95.84 %) the same way PERI (arXiv:1908.01466)
and FPPU (arXiv:2308.03425) validate their posit units.  posit8 has only
256 patterns, so here the validation is EXHAUSTIVE: all 256 x 256 operand
pairs through the fused Pallas elementwise kernels (``ops.vadd/vsub/vmul``
and both ``vdiv`` modes) and through the quire dot path (``ops.dot`` as a
length-1 reduction is an exactly-rounded multiply), bit-compared against
``core.softposit_ref``.  Every NaR/zero/minpos/maxpos row and every
round-to-nearest-even tie is covered — the sweep is what caught the
quire-lite's spurious-sticky tie-breaking bug (``core/dot.py``).

The full sweeps are ``slow``-marked (main-branch CI lane); a seeded
4096-pair subset of the same checks runs in the PR fast lane.

Thresholds: add/sub/mul and exact-mode div must match 100 %; the
paper-faithful Newton-Raphson divider (``nr3``) must meet the paper's
95.84 % (it measures 99.87 % on the exhaustive posit8 set).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import softposit_ref as ref
from repro.core.types import POSIT8
from repro.kernels import ops

PAPER_DIV_ACC = 0.9584          # paper §VI accuracy table, div row
N_FAST = 4096                   # seeded PR-lane subset


def _all_pairs():
    pats = np.arange(256, dtype=np.uint8)
    a, b = np.meshgrid(pats, pats, indexing="ij")
    return a.reshape(-1), b.reshape(-1)


# hard rows every fast run must cover: zero, NaR, minpos/maxpos
# saturation, and known RNE ties (minpos x 32 / 2^-20 x 2 sit exactly on
# the bit-string rounding midpoint — the class that exposed the quire
# sticky bug)
_NAR = POSIT8.nar_pattern
_MAXP = POSIT8.maxpos_pattern
_HARD_PAIRS = [(0, 0), (0, _NAR), (_NAR, 7), (_NAR, _NAR), (1, 1),
               (_MAXP, _MAXP), (_MAXP, 1), (1, 100), (100, 1), (2, 72),
               (139, 1), (3, 56), (5, 0), (0, 5), (_MAXP, _NAR), (1, 128)]


def _subset_pairs(n=N_FAST, seed=1234):
    a, b = _all_pairs()
    idx = np.random.default_rng(seed).choice(a.size, size=n, replace=False)
    ha = np.array([p for p, _ in _HARD_PAIRS], np.uint8)
    hb = np.array([q for _, q in _HARD_PAIRS], np.uint8)
    return (np.concatenate([ha, a[idx][:n - len(ha)]]),
            np.concatenate([hb, b[idx][:n - len(hb)]]))


def _ref_table(op, a, b):
    return np.array([op(int(x), int(y), POSIT8) for x, y in zip(a, b)],
                    np.uint8)


def _dot1(a, b, cfg):
    """ref.dot over a single pair: the golden for length-1 reductions."""
    return ref.dot([a], [b], cfg)


_KERNELS = {
    "add": (lambda a, b: ops.vadd(a, b, POSIT8), ref.add),
    "sub": (lambda a, b: ops.vsub(a, b, POSIT8), ref.sub),
    "mul": (lambda a, b: ops.vmul(a, b, POSIT8), ref.mul),
    "div_exact": (lambda a, b: ops.vdiv(a, b, POSIT8, mode="exact"),
                  ref.div),
    "div_nr3": (lambda a, b: ops.vdiv(a, b, POSIT8, mode="nr3"), ref.div),
    # length-1 quire reduction == exactly-rounded multiply; exercises
    # decode -> product -> quire placement -> normalize -> RNE encode
    "dot": (lambda a, b: ops.dot(a[:, None], b[:, None], POSIT8), _dot1),
}


def _accuracy(name, a, b):
    fn, gold = _KERNELS[name]
    got = np.asarray(fn(jnp.asarray(a), jnp.asarray(b))).astype(np.uint8)
    want = _ref_table(gold, a, b)
    return float((got == want).mean()), got, want


# ---------------------------------------------------------------------------
# exhaustive sweeps (main-branch lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("name", ["add", "sub", "mul", "div_exact", "dot"])
def test_exhaustive_posit8_exact_ops(name):
    """All 65536 pairs: exactly-rounded ops must match SoftPosit 100 %."""
    a, b = _all_pairs()
    acc, got, want = _accuracy(name, a, b)
    bad = np.nonzero(got != want)[0][:5]
    assert acc == 1.0, (
        f"{name}: {(got != want).sum()} / {a.size} mismatches, e.g. " +
        "; ".join(f"a={a[i]} b={b[i]} got={got[i]} want={want[i]}"
                  for i in bad))


@pytest.mark.slow
def test_exhaustive_posit8_div_nr3_meets_paper():
    """Newton-Raphson divider: >= the paper's 95.84 % on ALL pairs, and
    the special cases (x/0 = NaR, NaR absorbs, 0/x = 0) stay exact."""
    a, b = _all_pairs()
    acc, got, want = _accuracy("div_nr3", a, b)
    assert acc >= PAPER_DIV_ACC, f"nr3 div accuracy {acc:.4f}"
    nar = POSIT8.nar_pattern
    special = (a == nar) | (b == nar) | (a == 0) | (b == 0)
    np.testing.assert_array_equal(got[special], want[special])


@pytest.mark.slow
def test_exhaustive_pair_dots_through_longer_reductions():
    """Random length-16 posit8 dots (quire alignment + accumulation, not
    just the length-1 degenerate case) must match the golden exactly."""
    rng = np.random.default_rng(5)
    a = rng.integers(0, 256, (256, 16)).astype(np.uint8)
    b = rng.integers(0, 256, (256, 16)).astype(np.uint8)
    got = np.asarray(ops.dot(jnp.asarray(a), jnp.asarray(b),
                             POSIT8)).astype(np.uint8)
    want = np.array([ref.dot(a[i], b[i], POSIT8) for i in range(256)],
                    np.uint8)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# seeded fast-lane subset (same checks, 4096 pairs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["add", "sub", "mul", "div_exact", "dot"])
def test_fast_subset_exact_ops(name):
    a, b = _subset_pairs()
    acc, got, want = _accuracy(name, a, b)
    assert acc == 1.0, f"{name}: {(got != want).sum()} mismatches"


def test_fast_subset_div_nr3():
    a, b = _subset_pairs()
    acc, _, _ = _accuracy("div_nr3", a, b)
    assert acc >= PAPER_DIV_ACC


def test_fast_subset_covers_ties_and_extremes():
    """The seeded subset must keep exercising the hard rows: NaR, zero,
    minpos/maxpos, and at least one rounding TIE (the class of inputs
    that exposed the quire sticky bug) — guards against a future reseed
    quietly dropping the interesting cases."""
    a, b = _subset_pairs()
    nar, maxp = POSIT8.nar_pattern, POSIT8.maxpos_pattern
    for pat in (0, 1, nar, maxp):
        assert ((a == pat) | (b == pat)).any(), f"pattern {pat} not hit"
    # known tie: minpos * 32 sits exactly on the bit-string midpoint
    assert (((a == 1) & (b == 100)) | ((a == 100) & (b == 1))).any() or \
        (((a == 2) & (b == 72)) | ((a == 72) & (b == 2))).any(), \
        "subset lost all known RNE-tie pairs; change the seed"
