"""Benchmark delta-vs-baseline reporting regressions.

``benchmarks/run.py`` compares fresh suite rows against the committed
baselines under ``benchmarks/baselines/``.  Only one suite has a
committed baseline (serve), so the no-baseline path runs for every
other suite on every CI invocation — it must REPORT that state, not
crash and not silently skip (a silent skip reads as "no change" when
it means "nothing to compare against").  Corrupt or partially-matching
baselines must degrade to warnings too.
"""
import importlib.util
import json
import os

_RUN_PY = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       os.pardir, "benchmarks", "run.py")


def _load_run():
    spec = importlib.util.spec_from_file_location("bench_run", _RUN_PY)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


ROWS = [("row_a", 10.0, "tok_s=100.0 gen=8"),
        ("row_b", 5.0, "bytes=2048")]


def test_missing_baseline_reports_explicitly(tmp_path, capsys):
    run = _load_run()
    run._print_deltas("nosuch", ROWS, baselines_dir=str(tmp_path))
    err = capsys.readouterr().err
    assert "nosuch: no committed baseline" in err
    assert "BENCH_nosuch.json" in err          # says where to put one


def test_corrupt_baseline_warns_and_skips(tmp_path, capsys):
    run = _load_run()
    (tmp_path / "BENCH_bad.json").write_text("{not json")
    run._print_deltas("bad", ROWS, baselines_dir=str(tmp_path))
    assert "unreadable" in capsys.readouterr().err


def test_partial_baseline_flags_new_rows_and_deltas(tmp_path, capsys):
    run = _load_run()
    base = {"suite": "s", "rows": [
        {"name": "row_a", "us_per_call": 20.0,
         "derived": {"tok_s": 50.0, "gen": 8, "note": "text"}}]}
    (tmp_path / "BENCH_s.json").write_text(json.dumps(base))
    run._print_deltas("s", ROWS, baselines_dir=str(tmp_path))
    err = capsys.readouterr().err
    assert "row_a delta vs baseline" in err    # us halved, tok_s doubled
    assert "tok_s 50->100" in err
    assert "row_b: new row (no baseline)" in err


def test_committed_serve_baseline_is_readable():
    """The one committed baseline must parse and carry the decode-bytes
    metric the fused-kernel comparison reports."""
    path = os.path.join(os.path.dirname(_RUN_PY), "baselines",
                        "BENCH_serve.json")
    with open(path) as f:
        data = json.load(f)
    rows = {r["name"]: r for r in data["rows"]}
    fused = [r for name, r in rows.items()
             if name.startswith("serve_paged_fused")]
    assert fused, "baseline lacks the fused paged-decode row"
    derived = fused[0]["derived"]
    assert derived["decode_kv_B_tok_fused_posit16"] < \
        derived["decode_kv_B_tok_gather_posit16"]
