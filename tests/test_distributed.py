"""Distributed correctness on a real (8 fake-device) mesh, via subprocess
so the 512-device dry-run env var never leaks into other tests.

These tests *execute* the sharded programs (not just compile): the sharded
train step must match the single-device step numerically, and the
compressed cross-pod path must put uint16 all-gathers on the wire.
"""
import json
import os
import subprocess
import sys

import pytest

# 8-fake-device subprocess runs (compile-heavy): full lane only
pytestmark = pytest.mark.slow

_SCRIPT_NUMERIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.models import get_family
from repro.optim import adamw
from repro.runtime import sharding, train_loop
from repro.data.pipeline import DataConfig, Pipeline

cfg = configs.get_config("granite-moe-3b-a800m").reduced(
    compute_dtype="float32")
import dataclasses
cfg = dataclasses.replace(cfg, fsdp=False, seq_shard_activations=False)
fam = get_family(cfg)
opt_cfg = adamw.AdamWConfig(lr=1e-3)
pipe = Pipeline(DataConfig(seed=5), cfg, global_batch=8, seq_len=32)
batch = pipe.batch_at(0)

params = fam.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw.init(params, opt_cfg)
step_fn = train_loop.make_train_step(cfg, opt_cfg)

# single-device reference
p1, o1, m1 = jax.jit(step_fn)(params, opt, batch, jnp.asarray(0))

# sharded 4x2 mesh
mesh = jax.make_mesh((4, 2), ("data", "model"))
p_sh = sharding.param_shardings(params, mesh)
b_sh = sharding.to_shardings(sharding.batch_specs(batch, mesh, cfg), mesh)
params_s = jax.device_put(params, p_sh)
opt_s = jax.device_put(opt, sharding.param_shardings(opt, mesh))
batch_s = jax.device_put(batch, b_sh)
with sharding.set_mesh(mesh):
    p2, o2, m2 = jax.jit(step_fn)(params_s, opt_s, batch_s,
                                  jnp.asarray(0))

l1, l2 = float(m1["loss"]), float(m2["loss"])
dw = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                               b.astype(jnp.float32))))
         for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
print(json.dumps({"loss1": l1, "loss2": l2, "max_param_diff": dw}))
"""

_SCRIPT_COMPRESSED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import numpy as np
import jax, jax.numpy as jnp

from repro import configs
from repro.models import get_family
from repro.optim import adamw
from repro.runtime import sharding, train_loop
from repro.compress import gradient as gc
from repro.data.pipeline import DataConfig, Pipeline
from repro.launch.hlo_analysis import collective_bytes

cfg = configs.get_config("internvl2-1b").reduced(compute_dtype="float32")
cfg = dataclasses.replace(cfg, fsdp=False, seq_shard_activations=False,
                          batch_axes=("pod", "data"),
                          grad_compress="posit16", n_visual_tokens=0)
fam = get_family(cfg)
opt_cfg = adamw.AdamWConfig(lr=1e-3)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

params = fam.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw.init(params, opt_cfg)
ef = jax.tree.map(lambda p: jnp.zeros((2,) + p.shape, jnp.float32), params)
pipe = Pipeline(DataConfig(seed=9), cfg, global_batch=8, seq_len=32)
batch = pipe.batch_at(0)
tiled = jax.tree.map(lambda x: x.reshape((2, 4) + x.shape[1:]), batch)

step_fn = train_loop.make_train_step(cfg, opt_cfg, n_pods=2,
                                     compressed=True)
with sharding.set_mesh(mesh):
    jitted = jax.jit(step_fn)
    lowered = jitted.lower(params, opt, ef, tiled, jnp.asarray(0))
    compiled = lowered.compile()
    colls = collective_bytes(compiled.as_text())
    has_u16_gather = "u16" in compiled.as_text() and \
        colls.get("all-gather", 0) > 0
    # Execute the AOT executable compiled above.  Re-invoking ``jitted``
    # with explicitly device_put (committed-sharding) inputs forces a
    # second lowering whose SPMD partitioning pass is pathologically slow
    # (>10 min, XLA "Very slow compile" alarm) on jax 0.4.x CPU hosts
    # with 8 forced devices; the AOT call reuses the fast first compile
    # and the in-step sharding constraints still drive the collectives.
    p2, o2, ef2, m2 = compiled(params, opt, ef, tiled, jnp.asarray(0))
print(json.dumps({
    "loss": float(m2["loss"]),
    "colls": {k: int(v) for k, v in colls.items()},
    "has_u16_gather": bool(has_u16_gather),
    "ef_nonzero": bool(any(float(jnp.abs(x).max()) > 0
                           for x in jax.tree.leaves(ef2))),
}))
"""


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    r = _run(_SCRIPT_NUMERIC)
    assert abs(r["loss1"] - r["loss2"]) < 1e-4, r
    assert r["max_param_diff"] < 1e-4, r


def test_compressed_multipod_train_wire_is_posit16():
    r = _run(_SCRIPT_COMPRESSED)
    assert r["has_u16_gather"], r      # the pod sync moves uint16 patterns
    assert r["ef_nonzero"], r          # error feedback captured residue
    assert r["loss"] > 0
