"""Checkpointing: atomicity, resume, keep-k GC, posit payload, elastic."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layers": {"w": jnp.asarray(rng.standard_normal((8, 16)),
                                    jnp.float32),
                   "b": jnp.asarray(rng.standard_normal(16), jnp.float32)},
        "count": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = _tree()
    ck.save(10, t, blocking=True)
    assert ck.latest_step() == 10
    restored, step = ck.restore(10, t)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    ck.wait()
    ck._gc()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]


def test_interrupted_save_never_corrupts(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(5, _tree(5), blocking=True)
    # simulate a crash mid-save: a stale tmp dir must be ignored
    os.makedirs(tmp_path / "tmp.6")
    with open(tmp_path / "tmp.6" / "arrays.npz", "w") as f:
        f.write("garbage")
    assert ck.latest_step() == 5
    restored, _ = ck.restore(5, _tree())
    assert np.isfinite(np.asarray(restored["layers"]["w"])).all()


@pytest.mark.slow
def test_posit_payload_roundtrip_accuracy(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=1, posit_payload=True)
    t = _tree(3)
    ck.save(1, t, blocking=True)
    restored, _ = ck.restore(1, t)
    w0 = np.asarray(t["layers"]["w"])
    w1 = np.asarray(restored["layers"]["w"])
    # posit16 has >= 9 fraction bits around |x|~1: tight but lossy
    np.testing.assert_allclose(w1, w0, rtol=3e-3, atol=1e-4)
    # int leaves stay exact
    assert int(restored["count"]) == 7


def test_elastic_remesh_restore(tmp_path):
    """Save under one layout, restore under a different mesh — the
    checkpoint is mesh-agnostic (elastic scaling)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path), keep=1)
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ck.save(2, t, blocking=True)
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    restored, _ = ck.restore(2, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))
    assert restored["w"].sharding == sh["w"]
