"""Golden-model validation of the PVU core (paper §VI experiment).

The paper validates each vector op against SoftPosit, reporting 100 %
exact-match for add/sub/mul/dot and 95.84 % for div (Newton-Raphson
residual).  We reproduce that experiment with an exact Python golden model
(``softposit_ref``): integer/Fraction math, SoftPosit bit-string rounding.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (f32_to_posit, posit_to_f32, vpadd, vpdiv, vpdot,
                        vpmul, vpneg, vpsub)
from repro.core import softposit_ref as ref
from repro.core.types import POSIT16, POSIT32, PositConfig

CONFIGS = [
    PositConfig(8, 0),
    PositConfig(8, 2),
    PositConfig(16, 1),
    PositConfig(16, 2),
    PositConfig(32, 2),
]


def _rand_patterns(cfg, n, seed):
    rng = np.random.default_rng(seed)
    pats = rng.integers(0, 2 ** cfg.nbits, size=n, dtype=np.uint64)
    specials = np.array(
        [0, cfg.nar_pattern, cfg.maxpos_pattern, 1,
         (-1) & cfg.mask, (-cfg.maxpos_pattern) & cfg.mask], dtype=np.uint64)
    return np.concatenate([specials, pats]).astype(np.uint32)


def _gold_vec(fn, a, b, cfg):
    return np.array([fn(int(x), int(y), cfg) for x, y in zip(a, b)],
                    dtype=np.uint32)


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.name)
@pytest.mark.parametrize("op", ["add", "sub", "mul", "div_exact"])
def test_exact_ops_match_golden_100pct(cfg, op):
    """Paper claim: 100 % accuracy for add/sub/mul (and our beyond-paper
    exact divider)."""
    a = _rand_patterns(cfg, 300, seed=hash((cfg.nbits, cfg.es, op, 0)) % 2**31)
    b = _rand_patterns(cfg, 300, seed=hash((cfg.nbits, cfg.es, op, 1)) % 2**31)
    ja, jb = jnp.asarray(a), jnp.asarray(b)
    if op == "add":
        got = vpadd(ja, jb, cfg)
        want = _gold_vec(ref.add, a, b, cfg)
    elif op == "sub":
        got = vpsub(ja, jb, cfg)
        want = _gold_vec(ref.sub, a, b, cfg)
    elif op == "mul":
        got = vpmul(ja, jb, cfg)
        want = _gold_vec(ref.mul, a, b, cfg)
    else:
        got = vpdiv(ja, jb, cfg, mode="exact")
        want = _gold_vec(ref.div, a, b, cfg)
    got = np.asarray(got).astype(np.uint32)
    bad = np.nonzero(got != want)[0]
    assert bad.size == 0, (
        f"{op} {cfg.name}: {bad.size} mismatches; first at "
        f"a={a[bad[0]]:#x} b={b[bad[0]]:#x} got={got[bad[0]]:#x} "
        f"want={want[bad[0]]:#x}")


@pytest.mark.slow          # 256x256 pattern grid through the Fraction golden
def test_posit8_exhaustive_add_mul():
    """Exhaustive sweep over a full pattern grid for posit8."""
    cfg = PositConfig(8, 2)
    pats = np.arange(256, dtype=np.uint32)
    a = np.repeat(pats, 256).astype(np.uint32)
    b = np.tile(pats, 256).astype(np.uint32)
    for op, jfn, gfn in (("add", vpadd, ref.add), ("mul", vpmul, ref.mul)):
        got = np.asarray(jfn(jnp.asarray(a), jnp.asarray(b), cfg))
        got = got.astype(np.uint32)
        want = _gold_vec(gfn, a, b, cfg)
        bad = np.nonzero(got != want)[0]
        assert bad.size == 0, (
            f"{op}: {bad.size}/65536 mismatches; first a={a[bad[0]]:#x} "
            f"b={b[bad[0]]:#x} got={got[bad[0]]:#x} want={want[bad[0]]:#x}")


@pytest.mark.parametrize("cfg", [PositConfig(16, 2), PositConfig(32, 2),
                                 PositConfig(8, 1)], ids=lambda c: c.name)
def test_dot_matches_exact_quire_semantics(cfg):
    """Paper claim: 100 % accuracy for the dot product (single rounding)."""
    rng = np.random.default_rng(7)
    rows, length = 50, 24
    a = rng.integers(0, 2 ** cfg.nbits, size=(rows, length),
                     dtype=np.uint64).astype(np.uint32)
    b = rng.integers(0, 2 ** cfg.nbits, size=(rows, length),
                     dtype=np.uint64).astype(np.uint32)
    got = np.asarray(vpdot(jnp.asarray(a), jnp.asarray(b), cfg))
    got = got.astype(np.uint32)
    want = np.array([ref.dot(a[i], b[i], cfg) for i in range(rows)],
                    dtype=np.uint32)
    assert (got == want).all()


def _paperlike_quantized_values(rng, n):
    """Values shaped like the paper's test data: int8-quantized conv
    activations/weights dequantized to float (ResNet-18 first conv)."""
    q = rng.integers(-127, 128, size=n)
    scale = 0.02
    return (q * scale).astype(np.float64)


def test_div_nr3_accuracy_band():
    """Paper Table: division accuracy 95.84 % (NR-3 residual error).

    On paper-like quantized data the faithful NR-3 divider must land in
    the same band: >= 90 % but < 100 % exact match, while the exact
    divider is 100 %.
    """
    cfg = POSIT32
    rng = np.random.default_rng(11)
    n = 2000
    va = _paperlike_quantized_values(rng, n)
    vb = _paperlike_quantized_values(rng, n)
    vb[vb == 0] = 0.02  # avoid NaR rows; paper data has no zero weights
    a = np.array([ref.from_float(float(v), cfg) for v in va], dtype=np.uint32)
    b = np.array([ref.from_float(float(v), cfg) for v in vb], dtype=np.uint32)
    got = np.asarray(vpdiv(jnp.asarray(a), jnp.asarray(b), cfg, mode="nr3"))
    got = got.astype(np.uint32)
    want = _gold_vec(ref.div, a, b, cfg)
    acc = float((got == want).mean())
    assert 0.90 <= acc < 1.0, f"NR-3 div accuracy {acc:.4f} out of band"

    exact = np.asarray(vpdiv(jnp.asarray(a), jnp.asarray(b), cfg,
                             mode="exact")).astype(np.uint32)
    assert (exact == want).all()


@pytest.mark.parametrize("cfg", [POSIT16, POSIT32, PositConfig(8, 2)],
                         ids=lambda c: c.name)
def test_f32_conversion_exact(cfg):
    rng = np.random.default_rng(5)
    x = np.concatenate([
        (rng.standard_normal(300) * np.exp(rng.uniform(-30, 30, 300)))
        .astype(np.float32),
        np.array([0.0, -0.0, np.inf, -np.inf, np.nan, 1.0, -1.0,
                  1e-38, 1e38, 6e-39, 1e-44], np.float32),
    ])
    got = np.asarray(f32_to_posit(jnp.asarray(x), cfg)).astype(np.uint32)
    want = np.array([ref.from_float(float(v), cfg) for v in x],
                    dtype=np.uint32)
    assert (got == want).all()


def test_posit16_to_f32_exhaustive():
    cfg = POSIT16
    pats = np.arange(65536, dtype=np.uint32)
    f = np.asarray(posit_to_f32(jnp.asarray(pats), cfg))
    want = np.array([ref.to_float(int(p), cfg) for p in pats],
                    dtype=np.float32)
    both_nan = np.isnan(f) & np.isnan(want)
    assert ((f == want) | both_nan).all()


def test_posit32_to_f32_rne():
    cfg = POSIT32
    rng = np.random.default_rng(9)
    pats = rng.integers(0, 2 ** 32, size=2000, dtype=np.uint32)
    f = np.asarray(posit_to_f32(jnp.asarray(pats), cfg))
    want = np.array([np.float32(ref.to_float(int(p), cfg)) for p in pats],
                    dtype=np.float32)
    both_nan = np.isnan(f) & np.isnan(want)
    assert ((f == want) | both_nan).all()


def test_roundtrip_decode_encode_identity():
    from repro.core.pir import decode, encode_pir
    for cfg in CONFIGS:
        if cfg.nbits <= 16:
            pats = np.arange(2 ** cfg.nbits, dtype=np.uint32)
        else:
            rng = np.random.default_rng(3)
            pats = rng.integers(0, 2 ** 32, size=20000, dtype=np.uint32)
        back = np.asarray(encode_pir(decode(jnp.asarray(pats), cfg), cfg))
        assert (back.astype(np.uint32) == pats).all(), cfg.name


def test_nar_and_zero_propagation():
    cfg = POSIT32
    nar = np.uint32(cfg.nar_pattern)
    one = np.uint32(ref.from_float(1.0, cfg))
    zero = np.uint32(0)
    a = jnp.asarray([nar, one, zero, zero, one])
    b = jnp.asarray([one, nar, one, zero, zero])
    assert np.asarray(vpadd(a, b, cfg)).astype(np.uint32).tolist() == [
        int(nar), int(nar), int(one), 0, int(one)]
    assert np.asarray(vpmul(a, b, cfg)).astype(np.uint32).tolist() == [
        int(nar), int(nar), 0, 0, 0]
    # x / 0 = NaR per the standard
    d = np.asarray(vpdiv(jnp.asarray([one]), jnp.asarray([zero]), cfg,
                         mode="exact")).astype(np.uint32)
    assert d[0] == int(nar)


def test_negation_exact():
    cfg = POSIT16
    pats = np.arange(65536, dtype=np.uint32)
    neg = np.asarray(vpneg(jnp.asarray(pats), cfg)).astype(np.uint32)
    want = np.where((pats == 0) | (pats == cfg.nar_pattern), pats,
                    (-pats) & cfg.mask)
    assert (neg == want).all()
