"""Fault tolerance: crash/resume supervisor + straggler watchdog."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.fault import StragglerWatchdog, TrainSupervisor


def test_supervisor_recovers_from_failures(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    sup = TrainSupervisor(ck, save_every=5, max_restarts=3)
    crashed = {"done": False}

    def fail_hook(step):
        if step == 12 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    def step_fn(state, step):
        return {"x": state["x"] + 1}

    state, executed = sup.run(state={"x": jnp.asarray(0)},
                              step_fn=step_fn, total_steps=20,
                              fail_hook=fail_hook)
    # deterministic step function: final state == total steps regardless
    # of the replayed work after resume
    assert int(state["x"]) == 20
    kinds = [e[0] for e in sup.events]
    assert "failure" in kinds and "resume" in kinds
    assert executed > 20                       # some steps were replayed


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    sup = TrainSupervisor(ck, save_every=100, max_restarts=2)

    def fail_hook(step):
        raise RuntimeError("always failing")

    with pytest.raises(RuntimeError, match="max_restarts"):
        sup.run(state={"x": jnp.asarray(0)},
                step_fn=lambda s, i: s, total_steps=10,
                fail_hook=fail_hook)


def test_supervisor_resumes_fresh_process(tmp_path):
    """Simulates preemption: a NEW supervisor picks up the checkpoint."""
    ck1 = Checkpointer(str(tmp_path), keep=2)
    sup1 = TrainSupervisor(ck1, save_every=5)

    def boom(step):
        if step == 8:
            raise KeyboardInterrupt()

    try:
        sup1.run(state={"x": jnp.asarray(0)},
                 step_fn=lambda s, i: {"x": s["x"] + 1},
                 total_steps=20, fail_hook=boom)
    except BaseException:
        pass
    ck1.wait()          # the in-flight async save lands before "reboot"
    ck2 = Checkpointer(str(tmp_path), keep=2)
    sup2 = TrainSupervisor(ck2, save_every=5)
    state, _ = sup2.run(state={"x": jnp.asarray(0)},
                        step_fn=lambda s, i: {"x": s["x"] + 1},
                        total_steps=20)
    assert int(state["x"]) == 20
    assert ("resume", 5) in sup2.events


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=2.0, warmup=3)
    flags = [wd.observe(t) for t in
             [1.0, 1.0, 1.0, 1.1, 0.9, 5.0, 1.0, 1.05, 4.0]]
    assert flags[5] is True and flags[8] is True
    assert sum(flags) == 2
    assert wd.stragglers == 2
