"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward + train step + a decode step on CPU; output shapes and
finiteness are asserted.  (Full configs are exercised only via the
dry-run's ShapeDtypeStruct lowering.)
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import build

ARCHS = list(configs.ARCH_IDS)


def _smoke_batch(cfg, rng, batch=2, seq=32):
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(batch, seq)), jnp.int32)
    out = {"tokens": tokens}
    if cfg.family == "whisper":
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    if cfg.n_visual_tokens:
        out["visual"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_visual_tokens, cfg.d_model)),
            jnp.float32)
    return out


@pytest.mark.slow          # ~1 min across archs; decode-step smoke stays fast
@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = configs.get_config(arch).reduced()
    model = build(cfg)
    rng = np.random.default_rng(0)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, rng)

    loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: non-finite grads"
    # one SGD step then loss must still be finite (exercises the params)
    params2 = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                           params, grads)
    loss2 = model.train_loss(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = configs.get_config(arch).reduced()
    model = build(cfg)
    rng = np.random.default_rng(1)
    params = model.init_params(jax.random.PRNGKey(1))
    b, max_len = 2, 64
    cache = model.init_cache(b, max_len)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, size=(b,)), jnp.int32)
    logits, cache = model.decode_step(params, cache, tok)
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN logits"
    logits2, cache = model.decode_step(params, cache, tok)
    assert int(cache["len"]) == 2
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.slow          # full prefill + per-token decode across archs
@pytest.mark.parametrize("arch", ["phi3-medium-14b", "minicpm3-4b",
                                  "rwkv6-7b", "whisper-tiny"])
def test_prefill_matches_stepwise_decode(arch):
    """prefill(prompt) must agree with token-by-token decode_step."""
    # f32 compute: the two paths chunk differently, so bf16 rounding
    # order would dominate the comparison
    cfg = configs.get_config(arch).reduced(compute_dtype="float32")
    model = build(cfg)
    rng = np.random.default_rng(2)
    params = model.init_params(jax.random.PRNGKey(2))
    b, s = 1, 8
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, size=(b, s)), jnp.int32)
    kwargs = {}
    if cfg.family == "whisper":
        kwargs["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)

    cache_p, logits_p = model.prefill(params, tokens, **kwargs)

    cache = model.init_cache(b, s + 4)
    if cfg.family == "whisper":
        # seed the cross-attention cache from prefill (encoder-dependent)
        cache = dict(cache, ck=cache_p["ck"], cv=cache_p["cv"])
    logits_s = None
    for i in range(s):
        logits_s, cache = model.decode_step(params, cache, tokens[:, i])
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_s),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunked_matches_scan():
    """The chunk-parallel WKV engine must agree with the step recurrence."""
    from repro.models import rwkv6
    rng = np.random.default_rng(3)
    b, s, h, n = 2, 32, 3, 8
    r, k, v = (jnp.asarray(rng.standard_normal((b, s, h, n)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.2, 0.99, (b, s, h, n)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, n)), jnp.float32)
    st = jnp.asarray(rng.standard_normal((b, h, n, n)), jnp.float32)
    o1, s1 = rwkv6.wkv_scan(r, k, v, w, u, st)
    o2, s2 = rwkv6.wkv_chunked(r, k, v, w, u, st, chunk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunked_matches_step():
    """The chunked SSD engine must agree with stepwise decode updates."""
    from repro.models.hymba import ssd_chunked, ssd_step
    rng = np.random.default_rng(4)
    b, s, h, p, n = 2, 16, 3, 4, 5
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    bi = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    ci = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, (b, s, h)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 0.5, (h,)), jnp.float32)
    h0 = jnp.zeros((b, h, p, n), jnp.float32)

    y_c, h_c = ssd_chunked(x, bi, ci, dt, a_log, h0, chunk=4)

    hs = h0
    ys = []
    for t in range(s):
        y, hs = ssd_step(x[:, t], bi[:, t], ci[:, t], dt[:, t], a_log, hs)
        ys.append(y)
    y_s = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(hs),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_matches_naive():
    from repro.models import layers as L
    from repro.models.config import ModelConfig
    cfg = ModelConfig(attn_chunk_q=8, attn_chunk_kv=8)
    rng = np.random.default_rng(5)
    b, s, h, g, d = 2, 32, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, g, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, g, d)), jnp.float32)
    out = L.flash_attention(q, k, v, causal=True, cfg=cfg)

    # naive reference
    kk = jnp.repeat(k, h // g, axis=2)
    vv = jnp.repeat(v, h // g, axis=2)
    sc = jnp.einsum("bshd,bthd->bhst", q, kk) * d ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None], sc, -1e30)
    ref = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(sc, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_cond_skip_equivalent():
    from repro.models import layers as L
    from repro.models.config import ModelConfig
    rng = np.random.default_rng(6)
    b, s, h, d = 1, 64, 2, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    c1 = ModelConfig(attn_chunk_q=16, attn_chunk_kv=16, causal_skip="mask")
    c2 = ModelConfig(attn_chunk_q=16, attn_chunk_kv=16, causal_skip="cond")
    o1 = L.flash_attention(q, k, v, causal=True, cfg=c1)
    o2 = L.flash_attention(q, k, v, causal=True, cfg=c2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)
