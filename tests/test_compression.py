"""Posit gradient compression: error-feedback correctness + convergence."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compress import gradient as gc
from repro.compress import kvcache as kv
from repro.compress.kvcache import cache_bytes, dequantize_cache, \
    quantize_cache
from repro.core import softposit_ref as golden
from repro.core.types import POSIT16


def test_compress_decompress_close():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 1e-3,
                          jnp.float32)}
    e = gc.init_error_state(g)
    q, e2 = gc.compress_with_feedback(g, e, "posit16")
    back = gc.decompress(q, "posit16")
    # posit16 tapered precision: ~0.4% rel error at |x| ~ 1e-5
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(g["w"]),
                               rtol=6e-3, atol=1e-9)
    # residual == exactly what was lost
    np.testing.assert_allclose(
        np.asarray(e2["w"]),
        np.asarray(g["w"]) - np.asarray(back["w"]), atol=1e-12)


@pytest.mark.slow
def test_error_feedback_accumulates_small_gradients():
    """posit8 alone would flush tiny gradients; EF must recover them."""
    g = {"w": jnp.full((32,), 1e-4, jnp.float32)}   # tiny but consistent
    e = gc.init_error_state(g)
    total = np.zeros(32, np.float32)
    for _ in range(200):
        q, e = gc.compress_with_feedback(g, e, "posit8")
        total += np.asarray(gc.decompress(q, "posit8")["w"])
    # sum of transmitted gradients ~= sum of true gradients (bias -> 0)
    np.testing.assert_allclose(total, 200 * 1e-4 * np.ones(32), rtol=0.05)


@pytest.mark.slow
def test_ef_sgd_converges_on_quadratic():
    """EF-compressed SGD reaches the optimum of a quadratic."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    a = a @ a.T / 16 + jnp.eye(16)                  # PD
    b = jnp.asarray(rng.standard_normal(16), jnp.float32)
    x_star = jnp.linalg.solve(a, b)

    def grad(x):
        return a @ x - b

    x = jnp.zeros(16)
    e = {"x": jnp.zeros(16)}
    for _ in range(400):
        q, e = gc.compress_with_feedback({"x": grad(x)}, e, "posit8")
        x = x - 0.1 * gc.decompress(q, "posit8")["x"]
    err = float(jnp.linalg.norm(x - x_star) / jnp.linalg.norm(x_star))
    assert err < 2e-2, err


def test_kv_cache_quantization_ratio_and_error():
    rng = np.random.default_rng(2)
    cache = {"k": jnp.asarray(rng.standard_normal((2, 64, 4, 16)),
                              jnp.float32),
             "len": jnp.asarray(64, jnp.int32)}
    q8 = quantize_cache(cache, "posit8")
    q16 = quantize_cache(cache, "posit16")
    assert cache_bytes(q8) < cache_bytes(cache) / 3.9
    assert cache_bytes(q16) < cache_bytes(cache) / 1.9
    back = dequantize_cache(q16, "posit16")
    np.testing.assert_allclose(np.asarray(back["k"]),
                               np.asarray(cache["k"]), rtol=4e-3,
                               atol=1e-4)


def test_posit_moment_adamw_tracks_f32():
    """AdamW with posit16 first moments stays close to exact AdamW."""
    from repro.optim import adamw
    rng = np.random.default_rng(3)
    p0 = {"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
    cfg_a = adamw.AdamWConfig(lr=1e-2, posit_moments=False,
                              weight_decay=0.0)
    cfg_b = adamw.AdamWConfig(lr=1e-2, posit_moments=True,
                              weight_decay=0.0)
    pa = pb = p0
    sa = adamw.init(p0, cfg_a)
    sb = adamw.init(p0, cfg_b)
    for i in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(64) * 0.1, jnp.float32)}
        pa, sa, _ = adamw.update(g, sa, pa, cfg_a)
        pb, sb, _ = adamw.update(g, sb, pb, cfg_b)
    np.testing.assert_allclose(np.asarray(pb["w"]), np.asarray(pa["w"]),
                               rtol=2e-2, atol=2e-3)


# ---------------------------------------------------------------------------
# Posit-domain wire-format reductions / cache maintenance (fused kernels)
# ---------------------------------------------------------------------------

def _golden_vec(fn, a, b, cfg=POSIT16):
    return np.array([fn(int(x), int(y), cfg)
                     for x, y in zip(np.ravel(a), np.ravel(b))],
                    np.uint32).reshape(np.shape(a))


def _rand_wire(rng, shape, cfg=POSIT16):
    p = rng.integers(0, 2 ** cfg.nbits, size=shape, dtype=np.uint64)
    p[p == cfg.nar_pattern] = 1
    return p.astype(np.uint32)


def test_combine_and_scale_compressed_match_golden():
    """Wire-format add/scale == the SoftPosit golden, element by element
    (single rounding — no f32 round-trip anywhere)."""
    rng = np.random.default_rng(11)
    a = _rand_wire(rng, (32,))
    b = _rand_wire(rng, (32,))
    qa = {"w": jnp.asarray(a).astype(POSIT16.storage_dtype)}
    qb = {"w": jnp.asarray(b).astype(POSIT16.storage_dtype)}
    got = np.asarray(gc.combine_compressed(qa, qb, "posit16")["w"])
    assert (got.astype(np.uint32) == _golden_vec(golden.add, a, b)).all()

    s = 0.25
    spat = np.full_like(a, golden.from_float(s, POSIT16))
    got_s = np.asarray(gc.scale_compressed(qa, s, "posit16")["w"])
    assert (got_s.astype(np.uint32) == _golden_vec(golden.mul, a, spat)).all()


def test_mean_compressed_matches_golden_pairwise_tree():
    """mean over a power-of-two pod axis == pairwise golden adds followed
    by an exact (never-rounding) divide by the pod count."""
    rng = np.random.default_rng(12)
    pods = 4
    q = _rand_wire(rng, (pods, 16))
    tree = {"w": jnp.asarray(q).astype(POSIT16.storage_dtype)}
    got = np.asarray(gc.mean_compressed(tree, "posit16")["w"])
    s01 = _golden_vec(golden.add, q[0], q[1])
    s23 = _golden_vec(golden.add, q[2], q[3])
    total = _golden_vec(golden.add, s01, s23)
    npat = np.full_like(total, golden.from_float(float(pods), POSIT16))
    want = _golden_vec(golden.div, total, npat)
    assert (got.astype(np.uint32) == want).all()


def test_cache_scale_and_merge_posit_domain():
    """scale_cache/merge_caches transform pattern leaves in the posit
    domain, pass metadata through, and refuse inconsistent metadata."""
    rng = np.random.default_rng(13)
    k = _rand_wire(rng, (2, 8))
    v = _rand_wire(rng, (2, 8))
    mk = lambda kk, vv, ln: {
        "k": jnp.asarray(kk).astype(POSIT16.storage_dtype),
        "v": jnp.asarray(vv).astype(POSIT16.storage_dtype),
        "length": jnp.asarray(ln, jnp.int32)}
    cache = mk(k, v, 8)

    half = np.full_like(k, golden.from_float(0.5, POSIT16))
    scaled = kv.scale_cache(cache, 0.5, "posit16")
    assert (np.asarray(scaled["k"]).astype(np.uint32)
            == _golden_vec(golden.mul, k, half)).all()
    assert int(scaled["length"]) == 8          # metadata untouched

    other = mk(_rand_wire(rng, (2, 8)), _rand_wire(rng, (2, 8)), 8)
    merged = kv.merge_caches(cache, other, "posit16", weight_a=0.5)
    wk = _golden_vec(golden.add,
                     _golden_vec(golden.mul, k, half),
                     _golden_vec(golden.mul,
                                 np.asarray(other["k"], np.uint32), half))
    assert (np.asarray(merged["k"]).astype(np.uint32) == wk).all()

    with pytest.raises(ValueError, match="metadata"):
        kv.merge_caches(cache, mk(k, v, 10), "posit16")


def test_merge_caches_under_jit():
    """merge_caches used to crash with TracerBoolConversionError under
    jax.jit (the metadata guard called bool() on tracers); the guard is
    now trace-safe: jitted merge == eager merge, and static shape/dtype
    metadata mismatches still raise at trace time."""
    import jax
    rng = np.random.default_rng(29)
    mk = lambda kk, vv, ln: {
        "k": jnp.asarray(kk).astype(POSIT16.storage_dtype),
        "v": jnp.asarray(vv).astype(POSIT16.storage_dtype),
        "length": jnp.asarray(ln, jnp.int32)}
    a = mk(_rand_wire(rng, (2, 8)), _rand_wire(rng, (2, 8)), 8)
    b = mk(_rand_wire(rng, (2, 8)), _rand_wire(rng, (2, 8)), 8)

    eager = kv.merge_caches(a, b, "posit16", weight_a=0.25)
    jitted = jax.jit(
        lambda x, y: kv.merge_caches(x, y, "posit16", weight_a=0.25))(a, b)
    for leaf in ("k", "v", "length"):
        assert (np.asarray(eager[leaf]) == np.asarray(jitted[leaf])).all()

    bad = mk(_rand_wire(rng, (2, 8)), _rand_wire(rng, (2, 8)),
             np.asarray([8, 9]))               # shape-mismatched metadata
    with pytest.raises(ValueError, match="metadata"):
        jax.jit(lambda x, y: kv.merge_caches(x, y, "posit16"))(a, bad)


# ---------------------------------------------------------------------------
# Engine-shaped caches (preallocated ring-buffer serving engine)
# ---------------------------------------------------------------------------

def _engine_shaped_cache(rng, batch=2, cap=8, frontier=6):
    """A cache with the serving engine's metadata: scalar ``len`` write
    frontier, per-sequence ``lens``, preallocated ``max_len``."""
    return {
        "k": jnp.asarray(_rand_wire(rng, (2, batch, cap, 2, 4))).astype(
            POSIT16.storage_dtype),
        "v": jnp.asarray(_rand_wire(rng, (2, batch, cap, 2, 4))).astype(
            POSIT16.storage_dtype),
        "len": jnp.asarray(frontier, jnp.int32),
        "lens": jnp.asarray([frontier, frontier - 2], jnp.int32),
        "max_len": jnp.asarray(32, jnp.int32),
    }


def test_maintenance_ops_pass_engine_metadata_through():
    """scale_cache/merge_caches on engine-shaped caches must transform
    only the pattern leaves and pass len/lens/max_len through unchanged."""
    rng = np.random.default_rng(30)
    cache = _engine_shaped_cache(rng)

    scaled = kv.scale_cache(cache, 0.5, "posit16")
    for leaf in ("len", "lens", "max_len"):
        np.testing.assert_array_equal(np.asarray(scaled[leaf]),
                                      np.asarray(cache[leaf]))
    assert not (np.asarray(scaled["k"]) == np.asarray(cache["k"])).all()

    other = _engine_shaped_cache(rng)      # fresh patterns, same metadata
    merged = kv.merge_caches(cache, other, "posit16", weight_a=0.25)
    for leaf in ("len", "lens", "max_len"):
        np.testing.assert_array_equal(np.asarray(merged[leaf]),
                                      np.asarray(cache[leaf]))

    # inconsistent per-sequence lens must refuse to blend
    bad = dict(other, lens=jnp.asarray([1, 1], jnp.int32))
    with pytest.raises(ValueError, match="metadata"):
        kv.merge_caches(cache, bad, "posit16")


def test_cache_report_ring_buffer_ratios():
    """cache_report must give posit-vs-f32 ratios on window-sized
    (ring-buffer) caches: ~2x for posit16 K/V, ~4x for posit8."""
    import dataclasses

    from repro import configs
    from repro.models import transformer as T

    cfg = configs.get_config("phi3-medium-14b").reduced(
        compute_dtype="float32", sliding_window=8)
    for name, lo, hi in (("posit16", 1.9, 2.01), ("posit8", 3.5, 4.01)):
        c = dataclasses.replace(cfg, kv_posit=name)
        cache = T.init_cache(c, batch=2, max_len=64)
        assert cache["k"].shape[2] == 8        # ring: window-sized
        rep = kv.cache_report(cache)
        assert lo < rep["ratio"] <= hi, (name, rep)
        assert rep["bytes"] < rep["f32_bytes"]
    # f32 cache reports ~1x
    rep = kv.cache_report(T.init_cache(cfg, batch=2, max_len=64))
    assert 0.99 <= rep["ratio"] <= 1.01
