"""Posit gradient compression: error-feedback correctness + convergence."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.compress import gradient as gc
from repro.compress.kvcache import cache_bytes, dequantize_cache, \
    quantize_cache


def test_compress_decompress_close():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 1e-3,
                          jnp.float32)}
    e = gc.init_error_state(g)
    q, e2 = gc.compress_with_feedback(g, e, "posit16")
    back = gc.decompress(q, "posit16")
    # posit16 tapered precision: ~0.4% rel error at |x| ~ 1e-5
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(g["w"]),
                               rtol=6e-3, atol=1e-9)
    # residual == exactly what was lost
    np.testing.assert_allclose(
        np.asarray(e2["w"]),
        np.asarray(g["w"]) - np.asarray(back["w"]), atol=1e-12)


def test_error_feedback_accumulates_small_gradients():
    """posit8 alone would flush tiny gradients; EF must recover them."""
    g = {"w": jnp.full((32,), 1e-4, jnp.float32)}   # tiny but consistent
    e = gc.init_error_state(g)
    total = np.zeros(32, np.float32)
    for _ in range(200):
        q, e = gc.compress_with_feedback(g, e, "posit8")
        total += np.asarray(gc.decompress(q, "posit8")["w"])
    # sum of transmitted gradients ~= sum of true gradients (bias -> 0)
    np.testing.assert_allclose(total, 200 * 1e-4 * np.ones(32), rtol=0.05)


def test_ef_sgd_converges_on_quadratic():
    """EF-compressed SGD reaches the optimum of a quadratic."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    a = a @ a.T / 16 + jnp.eye(16)                  # PD
    b = jnp.asarray(rng.standard_normal(16), jnp.float32)
    x_star = jnp.linalg.solve(a, b)

    def grad(x):
        return a @ x - b

    x = jnp.zeros(16)
    e = {"x": jnp.zeros(16)}
    for _ in range(400):
        q, e = gc.compress_with_feedback({"x": grad(x)}, e, "posit8")
        x = x - 0.1 * gc.decompress(q, "posit8")["x"]
    err = float(jnp.linalg.norm(x - x_star) / jnp.linalg.norm(x_star))
    assert err < 2e-2, err


def test_kv_cache_quantization_ratio_and_error():
    rng = np.random.default_rng(2)
    cache = {"k": jnp.asarray(rng.standard_normal((2, 64, 4, 16)),
                              jnp.float32),
             "len": jnp.asarray(64, jnp.int32)}
    q8 = quantize_cache(cache, "posit8")
    q16 = quantize_cache(cache, "posit16")
    assert cache_bytes(q8) < cache_bytes(cache) / 3.9
    assert cache_bytes(q16) < cache_bytes(cache) / 1.9
    back = dequantize_cache(q16, "posit16")
    np.testing.assert_allclose(np.asarray(back["k"]),
                               np.asarray(cache["k"]), rtol=4e-3,
                               atol=1e-4)


def test_posit_moment_adamw_tracks_f32():
    """AdamW with posit16 first moments stays close to exact AdamW."""
    from repro.optim import adamw
    rng = np.random.default_rng(3)
    p0 = {"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
    cfg_a = adamw.AdamWConfig(lr=1e-2, posit_moments=False,
                              weight_decay=0.0)
    cfg_b = adamw.AdamWConfig(lr=1e-2, posit_moments=True,
                              weight_decay=0.0)
    pa = pb = p0
    sa = adamw.init(p0, cfg_a)
    sb = adamw.init(p0, cfg_b)
    for i in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(64) * 0.1, jnp.float32)}
        pa, sa, _ = adamw.update(g, sa, pa, cfg_a)
        pb, sb, _ = adamw.update(g, sb, pb, cfg_b)
    np.testing.assert_allclose(np.asarray(pb["w"]), np.asarray(pa["w"]),
                               rtol=2e-2, atol=2e-3)
