"""Hypothesis property tests for the posit core's algebraic invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt); skipping instead of aborting collection")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (f32_to_posit, posit_to_f32, vpadd, vpdiv, vpmul,
                        vpneg, vpsub)
from repro.core import softposit_ref as ref
from repro.core.types import POSIT16, POSIT32, PositConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref

pat16 = st.integers(min_value=0, max_value=2 ** 16 - 1)
pat32 = st.integers(min_value=0, max_value=2 ** 32 - 1)


def _np(p):
    return np.asarray(p).astype(np.uint32)


@settings(max_examples=200, deadline=None)
@given(a=pat16, b=pat16)
def test_add_commutative(a, b):
    cfg = POSIT16
    ja, jb = jnp.asarray([a], jnp.uint32), jnp.asarray([b], jnp.uint32)
    assert _np(vpadd(ja, jb, cfg))[0] == _np(vpadd(jb, ja, cfg))[0]


@settings(max_examples=200, deadline=None)
@given(a=pat16, b=pat16)
def test_mul_commutative(a, b):
    cfg = POSIT16
    ja, jb = jnp.asarray([a], jnp.uint32), jnp.asarray([b], jnp.uint32)
    assert _np(vpmul(ja, jb, cfg))[0] == _np(vpmul(jb, ja, cfg))[0]


@settings(max_examples=200, deadline=None)
@given(a=pat16, b=pat16)
def test_sub_is_add_neg(a, b):
    cfg = POSIT16
    ja, jb = jnp.asarray([a], jnp.uint32), jnp.asarray([b], jnp.uint32)
    assert _np(vpsub(ja, jb, cfg))[0] == _np(vpadd(ja, vpneg(jb, cfg), cfg))[0]


@settings(max_examples=200, deadline=None)
@given(a=pat16)
def test_add_zero_identity(a):
    cfg = POSIT16
    ja = jnp.asarray([a], jnp.uint32)
    z = jnp.asarray([0], jnp.uint32)
    assert _np(vpadd(ja, z, cfg))[0] == a


@settings(max_examples=200, deadline=None)
@given(a=pat16)
def test_x_minus_x_is_zero(a):
    cfg = POSIT16
    if a == cfg.nar_pattern:
        return
    ja = jnp.asarray([a], jnp.uint32)
    assert _np(vpsub(ja, ja, cfg))[0] == 0


@settings(max_examples=100, deadline=None)
@given(a=pat16)
def test_div_self_is_one(a):
    cfg = POSIT16
    if a == cfg.nar_pattern or a == 0:
        return
    ja = jnp.asarray([a], jnp.uint32)
    one = ref.from_float(1.0, cfg)
    assert _np(vpdiv(ja, ja, cfg, mode="exact"))[0] == one
    assert _np(vpdiv(ja, ja, cfg, mode="nr3"))[0] == one  # pow2 fast path


@settings(max_examples=150, deadline=None)
@given(a=pat32, b=pat32)
def test_add_matches_golden_posit32(a, b):
    cfg = POSIT32
    got = _np(vpadd(jnp.asarray([a], jnp.uint32),
                    jnp.asarray([b], jnp.uint32), cfg))[0]
    assert got == ref.add(a, b, cfg)


@settings(max_examples=150, deadline=None)
@given(a=pat32, b=pat32)
def test_mul_matches_golden_posit32(a, b):
    cfg = POSIT32
    got = _np(vpmul(jnp.asarray([a], jnp.uint32),
                    jnp.asarray([b], jnp.uint32), cfg))[0]
    assert got == ref.mul(a, b, cfg)


@settings(max_examples=100, deadline=None)
@given(x=st.floats(allow_nan=False, allow_infinity=False, width=32))
def test_f32_roundtrip_monotone_and_close(x):
    """quant_dequant is a contraction around representable values and the
    pattern encoding is monotone in value."""
    cfg = POSIT32
    p = f32_to_posit(jnp.asarray([x], jnp.float32), cfg)
    back = float(posit_to_f32(p, cfg)[0])
    if x != 0:
        assert np.sign(back) == np.sign(x)          # sign always survives
        if 1e-4 <= abs(x) <= 1e4:
            # >= 23 fraction bits in this band: roundtrip is f32-exact
            assert back == x


@settings(max_examples=40, deadline=None)
@given(a=pat16, b=pat16)
def test_fused_kernel_never_less_accurate_than_roundtrip(a, b):
    """The fused Pallas elementwise kernels round once (decode -> PIR op ->
    encode); the dequantize -> f32 op -> requantize composition rounds
    twice.  So for every op the fused result must be at least as close to
    the exact real result — and for add/sub/mul (single rounding vs an
    innocuous double rounding at posit16 widths) bit-identical to it."""
    cfg = POSIT16
    if a == cfg.nar_pattern or b == cfg.nar_pattern:
        return
    ja = jnp.asarray([a], jnp.uint32).astype(cfg.storage_dtype)
    jb = jnp.asarray([b], jnp.uint32).astype(cfg.storage_dtype)
    cases = [("add", kops.vadd(ja, jb, cfg)),
             ("sub", kops.vsub(ja, jb, cfg)),
             ("mul", kops.vmul(ja, jb, cfg)),
             ("div", kops.vdiv(ja, jb, cfg, mode="exact"))]
    for op, fused in cases:
        if op == "div" and b == 0:
            continue                     # x/0: NaR vs f32-inf edge
        fused = int(_np(fused)[0])
        rt = int(_np(kref.elementwise_roundtrip_ref(ja, jb, cfg, op))[0])
        golden_fn = {"add": ref.add, "sub": ref.sub, "mul": ref.mul,
                     "div": ref.div}[op]
        want = golden_fn(a, b, cfg)
        assert fused == want, (op, hex(a), hex(b))   # exactly rounded
        if op != "div":
            assert fused == rt, (op, hex(a), hex(b))
        # never less accurate: compare |value - exact| via the golden
        exact_a, exact_b = ref.decode_exact(a, cfg), ref.decode_exact(b, cfg)
        if exact_a in (ref.ZERO, ref.NAR) or exact_b in (ref.ZERO, ref.NAR):
            continue
        exact = {"add": exact_a + exact_b, "sub": exact_a - exact_b,
                 "mul": exact_a * exact_b, "div": exact_a / exact_b}[op]
        err_fused = abs(_exact_value(fused, cfg) - exact)
        err_rt = abs(_exact_value(rt, cfg) - exact)
        assert err_fused <= err_rt, (op, hex(a), hex(b))


def _exact_value(pattern: int, cfg):
    v = ref.decode_exact(pattern, cfg)
    return 0 if v in (ref.ZERO, ref.NAR) else v


@settings(max_examples=60, deadline=None)
@given(vals=st.lists(st.floats(min_value=-100, max_value=100,
                               allow_nan=False, width=32),
                     min_size=2, max_size=16))
def test_encoding_monotone(vals):
    """Posit patterns (as two's-complement ints) sort like their values."""
    cfg = POSIT16
    x = np.asarray(vals, np.float32)
    pats = _np(f32_to_posit(jnp.asarray(x), cfg))
    signed = pats.astype(np.int32)
    signed = np.where(signed >= 2 ** 15, signed - 2 ** 16, signed)
    decoded = np.asarray([ref.to_float(int(p), cfg) for p in pats])
    order_p = np.argsort(signed, kind="stable")
    assert (np.diff(decoded[order_p]) >= 0).all()


# ---------------------------------------------------------------------------
# Streaming-quire dot product: tiled == monolithic == exact quire
# ---------------------------------------------------------------------------

def _dot_cfg(nbits):
    from repro.core.types import POSIT8
    return {8: POSIT8, 16: POSIT16, 32: POSIT32}[nbits]


@pytest.mark.slow       # interpret-mode 4k-length kernel sweeps
@settings(max_examples=9, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       nbits=st.sampled_from([8, 16, 32]),
       length=st.sampled_from([4095, 4096, 4097]))
def test_tiled_dot_bit_identical_across_old_cap(seed, nbits, length):
    """Property (the tentpole's acceptance): for lengths straddling the
    old MAX_DOT_LENGTH=4096 boundary, the K-tiled kernel (forced
    multi-tile via block_k=1024) is bit-identical to the monolithic
    kernel (single tile, lengths <= 4096), to the streaming core
    reference, and — on bounded-spread data, where the 128-bit window is
    exact — to the 512-bit standard quire."""
    from repro.core import f32_to_posit
    from repro.kernels import posit_dot
    cfg = _dot_cfg(nbits)
    rng = np.random.default_rng(seed)
    x = (rng.uniform(1.0, 2.0, (2, length)) *
         rng.choice([-1.0, 1.0], (2, length))).astype(np.float32)
    y = (rng.uniform(1.0, 2.0, (2, length)) *
         rng.choice([-1.0, 1.0], (2, length))).astype(np.float32)
    ja = f32_to_posit(jnp.asarray(x), cfg)
    jb = f32_to_posit(jnp.asarray(y), cfg)

    tiled = _np(posit_dot.vpdot_rows(ja, jb, cfg, block_k=1024))
    core_ref = _np(kref.vpdot_rows_ref(ja, jb, cfg))
    quire = _np(kref.vpdot_quire_ref(ja, jb, cfg))
    assert (tiled == core_ref).all(), (nbits, length)
    assert (tiled == quire).all(), (nbits, length)
    if length <= 4096:                  # the original monolithic kernel
        mono = _np(posit_dot.vpdot_rows(ja, jb, cfg, block_k=length))
        assert (tiled == mono).all(), (nbits, length)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       nbits=st.sampled_from([8, 16, 32]))
def test_tiled_dot_matches_monolithic_random_patterns(seed, nbits):
    """Fast-lane variant: arbitrary random patterns (full exponent range,
    NaR included), short rows — forced K tiling must match the
    single-tile monolithic kernel bit for bit."""
    from repro.kernels import posit_dot
    cfg = _dot_cfg(nbits)
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2 ** cfg.nbits, (3, 192),
                     dtype=np.uint64).astype(np.uint32)
    b = rng.integers(0, 2 ** cfg.nbits, (3, 192),
                     dtype=np.uint64).astype(np.uint32)
    ja = jnp.asarray(a).astype(cfg.storage_dtype)
    jb = jnp.asarray(b).astype(cfg.storage_dtype)
    mono = _np(posit_dot.vpdot_rows(ja, jb, cfg))           # single tile
    core_ref = _np(kref.vpdot_rows_ref(ja, jb, cfg))
    assert (mono == core_ref).all(), (nbits, seed)
