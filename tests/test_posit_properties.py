"""Hypothesis property tests for the posit core's algebraic invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (f32_to_posit, posit_to_f32, vpadd, vpdiv, vpmul,
                        vpneg, vpsub)
from repro.core import softposit_ref as ref
from repro.core.types import POSIT16, POSIT32, PositConfig

pat16 = st.integers(min_value=0, max_value=2 ** 16 - 1)
pat32 = st.integers(min_value=0, max_value=2 ** 32 - 1)


def _np(p):
    return np.asarray(p).astype(np.uint32)


@settings(max_examples=200, deadline=None)
@given(a=pat16, b=pat16)
def test_add_commutative(a, b):
    cfg = POSIT16
    ja, jb = jnp.asarray([a], jnp.uint32), jnp.asarray([b], jnp.uint32)
    assert _np(vpadd(ja, jb, cfg))[0] == _np(vpadd(jb, ja, cfg))[0]


@settings(max_examples=200, deadline=None)
@given(a=pat16, b=pat16)
def test_mul_commutative(a, b):
    cfg = POSIT16
    ja, jb = jnp.asarray([a], jnp.uint32), jnp.asarray([b], jnp.uint32)
    assert _np(vpmul(ja, jb, cfg))[0] == _np(vpmul(jb, ja, cfg))[0]


@settings(max_examples=200, deadline=None)
@given(a=pat16, b=pat16)
def test_sub_is_add_neg(a, b):
    cfg = POSIT16
    ja, jb = jnp.asarray([a], jnp.uint32), jnp.asarray([b], jnp.uint32)
    assert _np(vpsub(ja, jb, cfg))[0] == _np(vpadd(ja, vpneg(jb, cfg), cfg))[0]


@settings(max_examples=200, deadline=None)
@given(a=pat16)
def test_add_zero_identity(a):
    cfg = POSIT16
    ja = jnp.asarray([a], jnp.uint32)
    z = jnp.asarray([0], jnp.uint32)
    assert _np(vpadd(ja, z, cfg))[0] == a


@settings(max_examples=200, deadline=None)
@given(a=pat16)
def test_x_minus_x_is_zero(a):
    cfg = POSIT16
    if a == cfg.nar_pattern:
        return
    ja = jnp.asarray([a], jnp.uint32)
    assert _np(vpsub(ja, ja, cfg))[0] == 0


@settings(max_examples=100, deadline=None)
@given(a=pat16)
def test_div_self_is_one(a):
    cfg = POSIT16
    if a == cfg.nar_pattern or a == 0:
        return
    ja = jnp.asarray([a], jnp.uint32)
    one = ref.from_float(1.0, cfg)
    assert _np(vpdiv(ja, ja, cfg, mode="exact"))[0] == one
    assert _np(vpdiv(ja, ja, cfg, mode="nr3"))[0] == one  # pow2 fast path


@settings(max_examples=150, deadline=None)
@given(a=pat32, b=pat32)
def test_add_matches_golden_posit32(a, b):
    cfg = POSIT32
    got = _np(vpadd(jnp.asarray([a], jnp.uint32),
                    jnp.asarray([b], jnp.uint32), cfg))[0]
    assert got == ref.add(a, b, cfg)


@settings(max_examples=150, deadline=None)
@given(a=pat32, b=pat32)
def test_mul_matches_golden_posit32(a, b):
    cfg = POSIT32
    got = _np(vpmul(jnp.asarray([a], jnp.uint32),
                    jnp.asarray([b], jnp.uint32), cfg))[0]
    assert got == ref.mul(a, b, cfg)


@settings(max_examples=100, deadline=None)
@given(x=st.floats(allow_nan=False, allow_infinity=False, width=32))
def test_f32_roundtrip_monotone_and_close(x):
    """quant_dequant is a contraction around representable values and the
    pattern encoding is monotone in value."""
    cfg = POSIT32
    p = f32_to_posit(jnp.asarray([x], jnp.float32), cfg)
    back = float(posit_to_f32(p, cfg)[0])
    if x != 0:
        assert np.sign(back) == np.sign(x)          # sign always survives
        if 1e-4 <= abs(x) <= 1e4:
            # >= 23 fraction bits in this band: roundtrip is f32-exact
            assert back == x


@settings(max_examples=60, deadline=None)
@given(vals=st.lists(st.floats(min_value=-100, max_value=100,
                               allow_nan=False, width=32),
                     min_size=2, max_size=16))
def test_encoding_monotone(vals):
    """Posit patterns (as two's-complement ints) sort like their values."""
    cfg = POSIT16
    x = np.asarray(vals, np.float32)
    pats = _np(f32_to_posit(jnp.asarray(x), cfg))
    signed = pats.astype(np.int32)
    signed = np.where(signed >= 2 ** 15, signed - 2 ** 16, signed)
    decoded = np.asarray([ref.to_float(int(p), cfg) for p in pats])
    order_p = np.argsort(signed, kind="stable")
    assert (np.diff(decoded[order_p]) >= 0).all()
