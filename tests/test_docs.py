"""Docs-freshness checks.

``docs/ARCHITECTURE.md`` is the layer map for the serving stack; it is
only useful while it tells the truth.  These tests parse every
backticked repo path out of the document (layer-map tables included)
and assert each one exists on disk — renaming or deleting a module
without updating the doc fails CI — and pin the README link that makes
the doc discoverable.
"""
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
ARCH = REPO / "docs" / "ARCHITECTURE.md"

# `src/repro/runtime/engine.py`, `tests/test_paged.py::test_x`,
# `compress/kvcache.py:BlockPool` — capture the path part only.
_PATH_RE = re.compile(r"`([\w.-]+(?:/[\w.-]+)+\.(?:py|md|json|yml|toml))")


def _doc_paths():
    paths = sorted(set(_PATH_RE.findall(ARCH.read_text())))
    assert paths, "ARCHITECTURE.md names no modules — parser broken?"
    return paths


def test_architecture_doc_exists_and_covers_the_stack():
    text = ARCH.read_text()
    # the layer map must name the full serving stack, bottom to top
    for mod in [
        "src/repro/compress/kvcache.py",
        "src/repro/models/layers.py",
        "src/repro/models/transformer.py",
        "src/repro/runtime/engine.py",
        "src/repro/runtime/scheduler.py",
        "src/repro/launch/serve.py",
        "benchmarks/bench_serve.py",
    ]:
        assert mod in text, f"layer map is missing {mod}"


def test_every_module_named_in_architecture_exists():
    missing = []
    for p in _doc_paths():
        if not ((REPO / p).exists() or (REPO / "src" / "repro" / p).exists()):
            missing.append(p)
    assert not missing, (
        "ARCHITECTURE.md names paths that do not exist (stale doc or "
        f"renamed module): {missing}"
    )


def test_readme_links_architecture_and_prefix_caching():
    readme = (REPO / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme
    assert "prefix" in readme.lower()
