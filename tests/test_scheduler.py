"""Continuous-batching scheduler regression tests.

The load-bearing invariant: a request scheduled into a slot pool —
admitted mid-stream at an arbitrary shared frontier, compacted around,
and retired early — must emit the BYTE-IDENTICAL token stream it would
emit alone through ``Engine.generate``.  Pinned on all three transformer
attention lanes (dense, MLA, sliding-window ring buffer), plus the cache
surgery ops (``reset_slots`` / ``compact`` / ``adopt_row``) and the
one-dispatch-per-chunk property that keeps admissions recompile-free.

PR 8 adds the chunked-prefill lane and the policy layer: prompts fed
through the decode lane in fixed-size chunks must stay byte-identical
with a FLAT engine compile count across arbitrarily ragged prompt
lengths, EDF admission must honor deadlines, and preemption-by-block-
release must restart a request token-identically without leaking a
single block under the sanitizer.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.compress import kvcache as kvc
from repro.models import get_family
from repro.models import transformer as T
from repro.runtime.engine import Engine
from repro.runtime.scheduler import Scheduler


def _cfg(lane):
    if lane == "mla":
        return configs.get_config("minicpm3-4b").reduced(
            compute_dtype="float32")
    cfg = configs.get_config("phi3-medium-14b").reduced(
        compute_dtype="float32")
    if lane == "window":
        cfg = dataclasses.replace(cfg, sliding_window=8, attn_chunk_kv=8)
    return cfg


def _params(cfg, seed=0):
    return get_family(cfg).init_params(jax.random.PRNGKey(seed), cfg)


# ---------------------------------------------------------------------------
# token identity: continuous batch == isolated generation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lane", ["dense", "mla", "window"])
def test_token_identity_with_midstream_admissions(lane):
    """Six requests through a two-slot pool (so admissions/retirements
    interleave with live decodes, and retired slots are recycled) must
    reproduce each request's isolated greedy stream byte for byte."""
    cfg = _cfg(lane)
    params = _params(cfg)
    rng = np.random.default_rng(3)
    plens = [5, 9, 3, 7, 4, 6]
    gens = [4, 8, 4, 8, 4, 8]
    prompts = [rng.integers(1, cfg.vocab, n).tolist() for n in plens]

    ref_eng = Engine(cfg, params, max_len=32, seed=0)     # greedy: key unused
    refs = [ref_eng.generate([p], g).tokens[0]
            for p, g in zip(prompts, gens)]

    sched = Scheduler(Engine(cfg, params, max_len=32, seed=0),
                      n_slots=2, chunk_size=4)
    rids = [sched.submit(p, g) for p, g in zip(prompts, gens)]
    done = sched.run(max_rounds=100)

    assert sched.n_admitted == 6 and sched.n_retired == 6
    for rid, ref, g in zip(rids, refs, gens):
        got = done[rid].tokens
        assert got.shape == (g,)
        np.testing.assert_array_equal(got, ref)


def test_token_identity_under_forced_compaction():
    """A max_len tight enough that the shared frontier must be pulled
    back between chunks (retired long rows unpin it) — identity must
    survive the cache rolls."""
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(4)
    plens = [5, 9, 3, 7, 4, 6]
    gens = [6, 12, 4, 9, 5, 7]
    prompts = [rng.integers(1, cfg.vocab, n).tolist() for n in plens]
    ref_eng = Engine(cfg, params, max_len=24, seed=0)
    refs = [ref_eng.generate([p], g).tokens[0]
            for p, g in zip(prompts, gens)]

    sched = Scheduler(Engine(cfg, params, max_len=24, seed=0),
                      n_slots=3, chunk_size=4)
    rids = [sched.submit(p, g) for p, g in zip(prompts, gens)]
    done = sched.run(max_rounds=100)
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(done[rid].tokens, ref)


def test_eos_stops_early_and_frees_the_slot():
    """Submitting with eos_id = the request's own 3rd greedy token must
    truncate the stream there and retire the slot for the next request."""
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, cfg.vocab, 6).tolist()
    ref = Engine(cfg, params, max_len=32, seed=0).generate([prompt], 8)
    eos = int(ref.tokens[0][2])

    sched = Scheduler(Engine(cfg, params, max_len=32, seed=0),
                      n_slots=1, chunk_size=4)
    rid = sched.submit(prompt, 8, eos_id=eos)
    rid2 = sched.submit(prompt, 8)            # queued behind the 1-slot pool
    done = sched.run(max_rounds=50)
    np.testing.assert_array_equal(done[rid].tokens, ref.tokens[0][:3])
    np.testing.assert_array_equal(done[rid2].tokens, ref.tokens[0])
    assert done[rid2].admitted_step >= done[rid].finished_step


# ---------------------------------------------------------------------------
# one compiled dispatch per decode chunk
# ---------------------------------------------------------------------------

def test_each_chunk_is_one_compiled_dispatch():
    """Admissions and retirements between chunks must never change the
    compiled computation: the whole run reuses ONE chunk callable, called
    exactly once per scheduling round that decodes."""
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(1, cfg.vocab, n).tolist() for n in [4, 6, 5]]

    eng = Engine(cfg, params, max_len=32, seed=0)
    calls = {"n": 0}
    real = eng._chunk_fn(4)

    def counted(*a):
        calls["n"] += 1
        return real(*a)

    eng._decode_jit[("chunk", 4)] = counted
    sched = Scheduler(eng, n_slots=2, chunk_size=4)
    for p in prompts:
        sched.submit(p, 6)
    sched.run(max_rounds=50)
    assert calls["n"] == sched.n_chunks > 0
    assert ("chunk", 4) in eng._decode_jit and \
        eng._decode_jit[("chunk", 4)] is counted, \
        "scheduler must reuse the cached chunk callable across admissions"


# ---------------------------------------------------------------------------
# chunked prefill: one compiled shape serves every request
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lane", ["dense", "mla", "window"])
def test_chunked_prefill_token_identity(lane):
    """Prompts fed through the decode lane in fixed-size chunks emit
    exactly the streams whole-prompt prefill emits, per lane, with the
    sanitizer armed and zero leaks."""
    cfg = _cfg(lane)
    params = _params(cfg)
    rng = np.random.default_rng(12)
    plens = [5, 9, 3, 7, 4, 6]
    gens = [4, 8, 4, 8, 4, 8]
    prompts = [rng.integers(1, cfg.vocab, n).tolist() for n in plens]
    ref_eng = Engine(cfg, params, max_len=32, paged=True, block_size=4)
    refs = [ref_eng.generate([p], g).tokens[0]
            for p, g in zip(prompts, gens)]

    eng = Engine(cfg, params, max_len=32, paged=True, block_size=4,
                 n_blocks=64, sanitize=True)
    sched = Scheduler(eng, n_slots=2, chunk_size=4, chunked_prefill=True)
    rids = [sched.submit(p, g) for p, g in zip(prompts, gens)]
    done = sched.run(max_rounds=200)
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(done[rid].tokens, ref)
    assert sched.n_leaked == 0 and not sched.leak_report()


def test_compile_count_flat_across_ragged_admissions():
    """Eight distinct prompt lengths through the chunked scheduler add
    ZERO lowered programs after warmup — the mixed dispatch shape
    depends only on (n_slots, chunk_size), never on a prompt length.
    (The unchunked admission path compiles one prefill per length;
    that specialization family no longer exists in chunked mode.)"""
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(13)
    eng = Engine(cfg, params, max_len=48, paged=True, block_size=4,
                 n_blocks=96)
    sched = Scheduler(eng, n_slots=2, chunk_size=4, chunked_prefill=True)
    for n in (3, 11):
        sched.submit(rng.integers(1, cfg.vocab, n).tolist(), 4)
    sched.run(max_rounds=200)
    warm_compiles = eng.n_compiles
    assert warm_compiles >= 1
    for n in (2, 5, 7, 9, 13, 17, 21, 26):      # 8 fresh distinct lengths
        sched.submit(rng.integers(1, cfg.vocab, n).tolist(), 6)
    sched.run(max_rounds=400)
    assert eng.n_compiles == warm_compiles
    assert sched.stats["n_compiles"] == warm_compiles


# ---------------------------------------------------------------------------
# policy layer: deadlines, EDF admission, preemption
# ---------------------------------------------------------------------------

def test_edf_admission_order():
    """A 1-slot pool admits by earliest deadline, not arrival order;
    best-effort (deadline-less) requests go last."""
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(14)
    prompts = [rng.integers(1, cfg.vocab, 5).tolist() for _ in range(3)]
    eng = Engine(cfg, params, max_len=32, paged=True, block_size=4,
                 n_blocks=32)
    sched = Scheduler(eng, n_slots=1, chunk_size=4, chunked_prefill=True)
    r_be = sched.submit(prompts[0], 4)               # best-effort, first in
    r_late = sched.submit(prompts[1], 4, deadline=100)
    r_soon = sched.submit(prompts[2], 4, deadline=50)
    done = sched.run(max_rounds=200)
    assert done[r_soon].admitted_step < done[r_late].admitted_step \
        < done[r_be].admitted_step


def test_preemption_restores_token_identity_and_leaks_nothing():
    """Overload: a deadline request that cannot fit preempts the
    best-effort row (block release is refcount-safe, sanitizer armed
    and poisoning the reclaims); the preempted request restarts from
    scratch and still emits its isolated greedy stream, and no block
    leaks."""
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(15)
    p_a = rng.integers(1, cfg.vocab, 8).tolist()
    p_b = rng.integers(1, cfg.vocab, 8).tolist()
    ref_eng = Engine(cfg, params, max_len=32, paged=True, block_size=4)
    ref_a = ref_eng.generate([p_a], 8).tokens[0]
    ref_b = ref_eng.generate([p_b], 8).tokens[0]

    # 6-block pool: one request's worst case is 5 blocks, so two can
    # never be resident together — the deadline MUST preempt
    eng = Engine(cfg, params, max_len=32, paged=True, block_size=4,
                 n_blocks=6, sanitize=True)
    sched = Scheduler(eng, n_slots=2, chunk_size=4, chunked_prefill=True)
    ra = sched.submit(p_a, 8)                    # best-effort
    sched.step()                                 # admitted, prefilling
    rb = sched.submit(p_b, 8, deadline=20)       # urgent, pool is full
    done = sched.run(max_rounds=300)
    assert sched.n_preempted >= 1
    assert done[rb].admitted_step < done[ra].admitted_step  # b cut in
    np.testing.assert_array_equal(done[ra].tokens, ref_a)
    np.testing.assert_array_equal(done[rb].tokens, ref_b)
    assert sched.n_leaked == 0 and not sched.leak_report()


def test_best_effort_never_preempts_best_effort():
    """Without deadlines the same overload just queues: no preemption
    (so no livelock risk), strict FIFO, streams untouched."""
    cfg = _cfg("dense")
    params = _params(cfg)
    rng = np.random.default_rng(16)
    p_a = rng.integers(1, cfg.vocab, 8).tolist()
    p_b = rng.integers(1, cfg.vocab, 8).tolist()
    eng = Engine(cfg, params, max_len=32, paged=True, block_size=4,
                 n_blocks=6, sanitize=True)
    sched = Scheduler(eng, n_slots=2, chunk_size=4, chunked_prefill=True)
    ra = sched.submit(p_a, 8)
    sched.step()
    rb = sched.submit(p_b, 8)                    # also best-effort
    done = sched.run(max_rounds=300)
    assert sched.n_preempted == 0
    assert done[rb].admitted_step >= done[ra].finished_step
    assert sched.n_leaked == 0 and not sched.leak_report()


# ---------------------------------------------------------------------------
# cache surgery ops
# ---------------------------------------------------------------------------

def _prefill_cache(cfg, params, rng, b, s, ml):
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (b, s)), jnp.int32)
    cache, logits = T.prefill(params, tokens, cfg, max_len=ml)
    return cache, logits


@pytest.mark.parametrize("lane", ["dense", "window"])
def test_compact_preserves_decode_logits(lane):
    """Rolling the frontier back and forth must not change what decode
    sees: logits after compaction == logits without it (both layouts)."""
    cfg = _cfg(lane)
    params = _params(cfg, seed=1)
    rng = np.random.default_rng(7)
    cache, logits = _prefill_cache(cfg, params, rng, 2, 10, 24)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    ref_logits, _ = T.decode_step(params, cache, tok, cfg)

    grown = kvc.compact(cache, target_len=17)     # push frontier up
    assert int(grown["len"]) == 17
    back = kvc.compact(grown)                     # default: max(lens) = 10
    assert int(back["len"]) == 10
    got_logits, _ = T.decode_step(params, back, tok, cfg)
    np.testing.assert_allclose(np.asarray(got_logits),
                               np.asarray(ref_logits), rtol=1e-5,
                               atol=1e-5)


def test_compact_rejects_target_beyond_max_len():
    cfg = _cfg("dense")
    params = _params(cfg, seed=1)
    cache, _ = _prefill_cache(cfg, params, np.random.default_rng(8),
                              1, 6, 16)
    with pytest.raises(ValueError, match="max_len"):
        kvc.compact(cache, target_len=17)


def test_reset_slots_zeroes_rows_and_lens():
    cfg = _cfg("dense")
    params = _params(cfg, seed=1)
    cache, _ = _prefill_cache(cfg, params, np.random.default_rng(9),
                              3, 8, 16)
    out = kvc.reset_slots(cache, jnp.asarray([True, False, True]))
    assert np.asarray(out["lens"]).tolist() == [0, 8, 0]
    assert int(np.abs(np.asarray(out["k"][:, 0])).sum()) == 0
    assert int(np.abs(np.asarray(out["k"][:, 2])).sum()) == 0
    # the surviving row and the shared metadata are untouched
    np.testing.assert_array_equal(np.asarray(out["k"][:, 1]),
                                  np.asarray(cache["k"][:, 1]))
    assert int(out["len"]) == int(cache["len"])
    assert int(out["max_len"]) == int(cache["max_len"])


def test_adopt_row_requires_frontier_headroom():
    cfg = _cfg("dense")
    params = _params(cfg, seed=1)
    rng = np.random.default_rng(10)
    pool, _ = _prefill_cache(cfg, params, rng, 2, 4, 16)
    row, _ = _prefill_cache(cfg, params, rng, 1, 7, 16)
    with pytest.raises(ValueError, match="frontier"):
        kvc.adopt_row(pool, row, 0)             # 7 > pool frontier 4
    pool = kvc.compact(pool, target_len=7)
    out = kvc.adopt_row(pool, row, 0)
    assert np.asarray(out["lens"]).tolist() == [7, 4]
    assert int(out["len"]) == 7


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_scheduler_rejects_unservable_request():
    cfg = _cfg("dense")
    params = _params(cfg)
    sched = Scheduler(Engine(cfg, params, max_len=16, seed=0),
                      n_slots=1, chunk_size=4)
    sched.submit([1, 2, 3], 10)                 # 3 + 10 - 1 + 4 = 16 fits
    with pytest.raises(ValueError, match="max_len"):
        sched.submit([1, 2, 3], 11)             # 17 > 16
    with pytest.raises(ValueError, match="empty"):
        sched.submit([], 4)


def test_scheduler_rejects_non_transformer_family():
    cfg = configs.get_config("rwkv6-7b").reduced(compute_dtype="float32")
    params = _params(cfg)
    with pytest.raises(ValueError, match="transformer"):
        Scheduler(Engine(cfg, params, max_len=16), n_slots=2)
