"""The sharding rule table must cover every param path of every family.

``runtime/sharding.py`` maps param paths to PartitionSpecs by regex,
first match wins — and an UNMATCHED path silently replicates, which is
exactly how a new projection ends up fully materialized on every TP
shard without anyone noticing.  These tests pin the covenant: every
leaf of every registered family matches a rule, and the only tolerated
rank mismatches (unstacked top-level norms hitting the stacked-norm
rule) are ones whose spec is fully replicated anyway, so no 'model'
placement is ever dropped by accident.

Also golden-pins the cache spec tables: the DENSE cache shards the KV
sequence axis over 'model' (context parallelism) while the PAGED arena
shards the head axis — same leaf names, different axis semantics — and
``make_host_mesh`` rounds a non-dividing tensor-parallel degree down
with a warning instead of crashing.
"""
import warnings

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import get_family
from repro.runtime import sharding as shd

SDS = jax.ShapeDtypeStruct


def _param_paths(arch):
    cfg = configs.get_config(arch).reduced(compute_dtype="float32")
    fam = get_family(cfg)
    shapes = jax.eval_shape(lambda k: fam.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    return jax.tree_util.tree_flatten_with_path(shapes)[0]


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_every_param_path_matches_a_rule(arch):
    missing = [shd._path_str(path)
               for path, leaf in _param_paths(arch)
               if shd.match_for_path(shd._path_str(path)) is None]
    assert not missing, (
        f"{arch}: param paths with NO sharding rule (these would "
        f"silently replicate on every TP shard): {missing}")


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_rank_mismatch_never_drops_a_model_placement(arch):
    # spec_for_path replicates on rank mismatch; that fallback is only
    # safe when the matched rule wanted replication in the first place
    for path, leaf in _param_paths(arch):
        ps = shd._path_str(path)
        pat, spec = shd.match_for_path(ps)
        if len(spec) != len(leaf.shape):
            assert all(e is None for e in spec), (
                f"{arch}: {ps} (shape {leaf.shape}) matched rule "
                f"{pat!r} of rank {len(spec)} carrying a mesh axis — "
                f"the rank-mismatch fallback would silently drop it")


def test_match_for_path_can_miss():
    # the coverage test above is vacuous if the matcher never misses
    assert shd.match_for_path("no/such/param") is None


class _FakeMesh:
    """Duck-typed mesh for spec goldens: filter_spec / batch_axes only
    read ``axis_names`` and ``shape``, so divisibility rules can be
    exercised without 8 real devices."""
    axis_names = ("data", "model")
    shape = {"data": 2, "model": 4}


def _cfg():
    return configs.get_config("phi3-medium-14b").reduced(
        compute_dtype="float32")


def test_dense_cache_spec_shards_sequence_axis():
    # dense KV (L, B, T, G, hd): 'model' rides the SEQUENCE axis
    specs = shd.cache_specs(
        {"k": SDS((2, 3, 16, 4, 8), jnp.float32),
         "v": SDS((2, 3, 16, 4, 8), jnp.float32),
         "len": SDS((), jnp.int32)},
        _FakeMesh(), _cfg())
    assert specs["k"] == P(None, None, "model", None, None)
    assert specs["v"] == P(None, None, "model", None, None)
    assert specs["len"] == P()


def test_paged_cache_spec_shards_head_axis():
    # paged arena (L, nb, bs, G, hd): axis 1 is the block id and axis 2
    # the in-block slot, so 'model' must ride the HEAD axis instead;
    # MLA latents (no head axis) and block-table metadata replicate
    specs = shd.paged_cache_specs(
        {"k": SDS((2, 8, 4, 4, 8), jnp.float32),
         "v": SDS((2, 8, 4, 4, 8), jnp.float32),
         "c_kv": SDS((2, 8, 4, 6), jnp.float32),
         "k_rope": SDS((2, 8, 4, 8), jnp.float32),
         "block_tables": SDS((3, 5), jnp.int32),
         "lens": SDS((3,), jnp.int32),
         "max_len": SDS((), jnp.int32)},
        _FakeMesh(), _cfg())
    assert specs["k"] == P(None, None, None, "model", None)
    assert specs["v"] == P(None, None, None, "model", None)
    for name in ("c_kv", "k_rope", "block_tables", "lens", "max_len"):
        assert all(e is None for e in specs[name]), (
            f"{name} must replicate, got {specs[name]}")


def test_paged_cache_spec_replicates_non_dividing_heads():
    # 2 KV heads on a 'model'=4 mesh: explicit placement needs exact
    # divisibility, so the filter falls back to replication rather
    # than letting device_put crash
    specs = shd.paged_cache_specs(
        {"k": SDS((2, 8, 4, 2, 8), jnp.float32)}, _FakeMesh(), _cfg())
    assert all(e is None for e in specs["k"])


def test_make_host_mesh_rounds_down_and_warns():
    n = len(jax.devices())
    with pytest.warns(UserWarning, match="rounding down"):
        mesh = make_host_mesh(n + 3)       # can never divide n
    assert mesh.shape["model"] <= n
    assert n % mesh.shape["model"] == 0
    assert mesh.shape["data"] * mesh.shape["model"] == n


def test_make_host_mesh_exact_degree_is_silent():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        mesh = make_host_mesh(1)
    assert not [x for x in w if "rounding down" in str(x.message)]
    assert mesh.shape["model"] == 1
