"""Paper Listing 2: 4x4 convolution on the PVU, vectorized by rows.

The kernel rows are loaded as posit vectors, multiplied with vpmul/vpdot,
and accumulated — exactly the paper's ``conv4x4_vectorized``.  The input
is int8-quantized activations/weights (the §VI methodology).  Output is
compared against exact f64 convolution.

The bias-add epilogue runs on the fused Pallas elementwise kernel
(``repro.kernels.ops.vadd``): conv output patterns + bias pattern stay in
the posit domain end to end — no dequantize -> f32 add -> requantize.

  PYTHONPATH=src python examples/posit_convolution.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import POSIT32, f32_to_posit, posit_to_f32, vpdot
from repro.kernels import ops as kops


def conv4x4_posit(image, kernel):
    """image: (H, W) f32; kernel: (4, 4) f32 -> (H-3, W-3) f32, all math
    in posit32 with the PVU dot product (single rounding per window)."""
    h, w = image.shape
    oh, ow = h - 3, w - 3
    # im2col: every output pixel's 16-tap window as one row
    windows = np.lib.stride_tricks.sliding_window_view(image, (4, 4))
    rows = windows.reshape(oh * ow, 16).astype(np.float32)
    krow = np.broadcast_to(kernel.reshape(1, 16), (oh * ow, 16))
    pa = f32_to_posit(jnp.asarray(rows), POSIT32)
    pb = f32_to_posit(jnp.asarray(krow.astype(np.float32)), POSIT32)
    out = vpdot(pa, pb, POSIT32)              # paper's vpdot instruction
    return (np.asarray(posit_to_f32(out, POSIT32)).reshape(oh, ow),
            np.asarray(out).astype(np.uint32))


def main():
    rng = np.random.default_rng(7)
    # paper §VI: int8-quantized first-conv data
    image = (rng.integers(0, 128, (32, 32)) * 0.02).astype(np.float32)
    kernel = (rng.integers(-127, 128, (4, 4)) * 0.005).astype(np.float32)

    out_posit, out_patterns = conv4x4_posit(image, kernel)

    # exact reference in f64
    ref = np.zeros((29, 29))
    for i in range(29):
        for j in range(29):
            ref[i, j] = np.sum(image[i:i + 4, j:j + 4].astype(np.float64)
                               * kernel.astype(np.float64))

    abs_err = np.abs(out_posit - ref)
    rel = abs_err.max() / max(np.abs(ref).max(), 1e-12)
    # quire exactness: each window must be the *correctly rounded* posit32
    # of the exact real dot product (paper claim: 100 % for vpdot)
    from repro.core import softposit_ref as golden
    want = np.array([golden.from_float(float(v), POSIT32)
                     for v in ref.reshape(-1)], np.uint32)
    exact_pct = float((out_patterns == want).mean())
    print(f"conv 32x32 * 4x4 -> 29x29 via PVU vpdot")
    print(f"max abs err vs f64:     {abs_err.max():.3e}")
    print(f"max rel err vs f64:     {rel:.3e}")
    print(f"correctly-rounded:      {100 * exact_pct:.2f}% of windows "
          f"(single rounding per window)")
    assert rel < 1e-6 and exact_pct == 1.0

    # bias-add epilogue: fused posit vadd (decode->add->encode in one
    # Pallas pass), checked against the golden model per element
    bias = 0.125
    bias_pat = jnp.asarray(golden.from_float(bias, POSIT32),
                           POSIT32.storage_dtype)
    with_bias = np.asarray(
        kops.vadd(jnp.asarray(out_patterns), bias_pat, POSIT32))
    want_bias = np.array(
        [golden.add(int(p), int(bias_pat), POSIT32)
         for p in out_patterns.reshape(-1)],
        np.uint32).reshape(with_bias.shape)
    assert (with_bias == want_bias).all()
    f_bias = np.asarray(posit_to_f32(jnp.asarray(with_bias), POSIT32))
    print(f"fused bias-add (+{bias}): exact on "
          f"{with_bias.size}/{with_bias.size} outputs, "
          f"mean={f_bias.mean():.4f} (unbiased mean={out_posit.mean():.4f})")
    print("OK")


if __name__ == "__main__":
    main()
