"""Elastic scaling demo: train, 'lose' half the cluster, resume.

Trains a small LM on a 2-device mesh, checkpoints, then restores the
same checkpoint onto a 1-device mesh (different sharding layout) and
continues — loss continues from where it left off.  This is the
mesh-agnostic checkpoint path that lets a 512-chip job resume on 256
chips after losing a pod.

  PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs  # noqa: E402
from repro.checkpoint.checkpointer import Checkpointer  # noqa: E402
from repro.data.pipeline import DataConfig, Pipeline  # noqa: E402
from repro.models import get_family  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime import sharding, train_loop  # noqa: E402


def main():
    cfg = configs.get_config("gemma-7b").reduced(compute_dtype="float32")
    fam = get_family(cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    pipe = Pipeline(DataConfig(seed=17), cfg, global_batch=8, seq_len=64)
    step_fn = jax.jit(train_loop.make_train_step(cfg, opt_cfg))
    ckpt = Checkpointer("/tmp/repro_elastic_ckpt", keep=1)

    # ---- phase 1: 2-device mesh (data x model = 2 x 1)
    mesh2 = jax.make_mesh((2, 1), ("data", "model"))
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params, opt_cfg)
    p_sh2 = sharding.param_shardings(params, mesh2)
    params = jax.device_put(params, p_sh2)
    losses = []
    with sharding.set_mesh(mesh2):
        for i in range(20):
            params, opt, m = step_fn(params, opt, pipe.batch_at(i),
                                     jnp.asarray(i, jnp.int32))
            losses.append(float(m["loss"]))
    ckpt.save(20, {"params": params, "opt": opt}, blocking=True)
    print(f"phase 1 (2 devices): loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"checkpoint at step 20")

    # ---- phase 2: 'a device died' -> resume on a 1-device mesh
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    template = {"params": params, "opt": opt}
    sh1 = {
        "params": sharding.param_shardings(params, mesh1),
        "opt": sharding.param_shardings(opt, mesh1),
    }
    state, step0 = ckpt.restore(20, template, shardings=sh1)
    params1, opt1 = state["params"], state["opt"]
    with sharding.set_mesh(mesh1):
        resumed = []
        for i in range(step0, step0 + 10):
            params1, opt1, m = step_fn(params1, opt1, pipe.batch_at(i),
                                       jnp.asarray(i, jnp.int32))
            resumed.append(float(m["loss"]))
    print(f"phase 2 (1 device, restored step {step0}): "
          f"loss {resumed[0]:.3f} -> {resumed[-1]:.3f}")
    assert resumed[0] < losses[0], "resume lost training progress"
    print("OK: elastic re-mesh resume preserved progress")


if __name__ == "__main__":
    main()
