"""End-to-end training driver: a small LM on this repo's own text.

Uses the full framework stack: config -> model (reduced gemma family) ->
deterministic byte-level pipeline over README/DESIGN docs -> AdamW (with
posit16 moments) -> supervised loop with async checkpoints + resume +
straggler watchdog.  Loss must drop substantially within a few hundred
steps.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import glob
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_main  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="gemma-7b")
    args = ap.parse_args()

    # build a self-contained corpus out of the repo's documentation
    root = os.path.join(os.path.dirname(__file__), "..")
    corpus = "/tmp/repro_corpus.txt"
    with open(corpus, "w") as out:
        for pattern in ("*.md", "src/repro/core/*.py"):
            for path in sorted(glob.glob(os.path.join(root, pattern))):
                out.write(open(path).read())

    losses = train_main.main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "16", "--seq", "128",
        "--data", "bytes", "--corpus", corpus,
        "--lr", "1e-3", "--posit-moments",
        "--ckpt-dir", "/tmp/repro_train_lm_ckpt",
        "--save-every", "100",
    ])
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"mean loss first-10={first:.3f} last-10={last:.3f}")
    assert last < first * 0.8, "loss did not improve"
    print("OK: model learned")


if __name__ == "__main__":
    main()
