"""Serve a small model with batched requests + posit KV cache.

Builds the preallocated-cache serving engine, generates greedily twice —
once with an f32 cache, once with the paper's posit16 cache — and reports
the byte saving and the agreement of the generated tokens.  The engine
decodes the whole generation in one compiled ``lax.scan`` and, unlike the
old per-step loop, never clamp-overwrites the final cache slot: every
decode token lands in preallocated headroom.

The second half A/Bs the two cache LAYOUTS on the posit16 engine: the
dense ``batch x max_len`` preallocation versus the paged block-table
arena (``Engine(paged=True)``), which must produce byte-identical tokens
while only allocating the blocks the ragged prompts actually touch.

  PYTHONPATH=src python examples/serve_posit_kv.py
"""
import dataclasses
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.compress.kvcache import cache_report  # noqa: E402
from repro.models import get_family  # noqa: E402
from repro.runtime.engine import Engine  # noqa: E402

PROMPT_LEN, GEN = 24, 16


def generate(cfg, params, prompts, n_steps, **engine_kw):
    engine = Engine(cfg, params, max_len=PROMPT_LEN + GEN, seed=0,
                    **engine_kw)
    res = engine.generate(prompts, n_steps)
    return res.tokens, res.cache, engine


def main():
    base = configs.get_config("phi3-medium-14b").reduced(
        compute_dtype="float32")
    fam = get_family(base)
    params = fam.init_params(jax.random.PRNGKey(0), base)
    rng = np.random.default_rng(3)
    prompts = rng.integers(1, base.vocab, (4, PROMPT_LEN))

    gen_f32, cache_f32, _ = generate(base, params, prompts, GEN)
    cfg_q = dataclasses.replace(base, kv_posit="posit16")
    gen_q, cache_q, _ = generate(cfg_q, params, prompts, GEN)

    agree = float((gen_f32 == gen_q).mean())
    rep_f32, rep_q = cache_report(cache_f32), cache_report(cache_q)
    print(f"batched serve: 4 requests x {PROMPT_LEN}-token prompts, "
          f"+{GEN} decodes (one scan, preallocated max_len="
          f"{PROMPT_LEN + GEN})")
    print(f"cache bytes  f32:     {rep_f32['bytes']:,}")
    print(f"cache bytes  posit16: {rep_q['bytes']:,} "
          f"({rep_f32['bytes'] / rep_q['bytes']:.2f}x smaller)")
    print(f"greedy tokens agree:  {100 * agree:.1f}%")
    print("f32 cache sample   :", gen_f32[0][:10])
    print("posit16 cache sample:", gen_q[0][:10])
    assert agree > 0.9, "posit16 KV cache changed generations materially"

    # dense vs paged layout on ragged prompts: identical tokens, fewer
    # blocks resident than the dense worst case
    ragged = [rng.integers(1, base.vocab, n).tolist()
              for n in (PROMPT_LEN, PROMPT_LEN // 2, PROMPT_LEN // 3, 8)]
    dense_toks, dense_cache, _ = generate(cfg_q, params, ragged, GEN)
    paged_toks, paged_cache, eng = generate(
        cfg_q, params, ragged, GEN, paged=True, block_size=8)
    rep_d, rep_p = cache_report(dense_cache), cache_report(paged_cache)
    used = eng.pool.peak_in_use
    print(f"paged layout (block_size=8): tokens identical = "
          f"{bool((dense_toks == paged_toks).all())}")
    print(f"blocks in use: {used} of {eng.pool.n_blocks} worst-case "
          f"({rep_p['bytes']:,} B arena vs {rep_d['bytes']:,} B dense)")
    assert (dense_toks == paged_toks).all(), \
        "paged cache layout changed the generated tokens"
    print("OK")


if __name__ == "__main__":
    main()
