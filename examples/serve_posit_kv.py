"""Serve a small model with batched requests + posit KV cache.

Runs prefill on a batch of prompts and decodes greedily twice — once with
an f32 cache, once with the paper's posit16 cache — and reports the byte
saving and the agreement of the generated tokens.

  PYTHONPATH=src python examples/serve_posit_kv.py
"""
import dataclasses
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.compress.kvcache import cache_bytes  # noqa: E402
from repro.models import get_family  # noqa: E402


def generate(cfg, params, tokens, n_steps):
    fam = get_family(cfg)
    prefill = jax.jit(lambda p, t: fam.prefill(p, t, cfg))
    decode = jax.jit(lambda p, c, t: fam.decode_step(p, c, t, cfg))
    cache, logits = prefill(params, tokens)
    outs = [jnp.argmax(logits, -1).astype(jnp.int32)]
    for _ in range(n_steps):
        logits, cache = decode(params, cache, outs[-1])
        outs.append(jnp.argmax(logits, -1).astype(jnp.int32))
    return np.stack([np.asarray(t) for t in outs], 1), cache


def main():
    base = configs.get_config("phi3-medium-14b").reduced(
        compute_dtype="float32")
    fam = get_family(base)
    params = fam.init_params(jax.random.PRNGKey(0), base)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(1, base.vocab, (4, 24)), jnp.int32)

    gen_f32, cache_f32 = generate(base, params, tokens, 16)
    cfg_q = dataclasses.replace(base, kv_posit="posit16")
    gen_q, cache_q = generate(cfg_q, params, tokens, 16)

    agree = float((gen_f32 == gen_q).mean())
    print(f"batched serve: 4 requests x 24-token prompts, +16 decodes")
    print(f"cache bytes  f32:     {cache_bytes(cache_f32):,}")
    print(f"cache bytes  posit16: {cache_bytes(cache_q):,} "
          f"({cache_bytes(cache_f32) / cache_bytes(cache_q):.2f}x smaller)")
    print(f"greedy tokens agree:  {100 * agree:.1f}%")
    print("f32 cache sample   :", gen_f32[0][:10])
    print("posit16 cache sample:", gen_q[0][:10])
    assert agree > 0.9, "posit16 KV cache changed generations materially"
    print("OK")


if __name__ == "__main__":
    main()
