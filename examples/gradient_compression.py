"""Cross-pod posit gradient compression: convergence demonstration.

Trains the same small LM twice — exact f32 gradients vs error-feedback
posit8-compressed gradients (the cross-pod wire format) — and shows the
loss curves stay together while the wire bytes drop 4x.

  PYTHONPATH=src python examples/gradient_compression.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.compress import gradient as gc  # noqa: E402
from repro.data.pipeline import DataConfig, Pipeline  # noqa: E402
from repro.models import get_family  # noqa: E402
from repro.optim import adamw  # noqa: E402


def main():
    cfg = configs.get_config("internvl2-1b").reduced(
        compute_dtype="float32", n_visual_tokens=0)
    fam = get_family(cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, weight_decay=0.0)
    pipe = Pipeline(DataConfig(seed=11), cfg, global_batch=16, seq_len=64)

    def loss_fn(p, batch):
        return fam.train_loss(p, batch, cfg)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def apply(p, s, g):
        return adamw.update(g, s, p, opt_cfg)

    def train(compress: bool, steps: int = 120):
        params = fam.init_params(jax.random.PRNGKey(0), cfg)
        state = adamw.init(params, opt_cfg)
        ef = gc.init_error_state(params) if compress else None
        losses, wire_bytes = [], 0
        for step in range(steps):
            batch = pipe.batch_at(step)
            loss, grads = grad_fn(params, batch)
            if compress:
                q, ef = gc.compress_with_feedback(grads, ef, "posit8")
                wire_bytes += sum(x.size * x.dtype.itemsize
                                  for x in jax.tree.leaves(q))
                grads = gc.decompress(q, "posit8")
            else:
                wire_bytes += sum(
                    x.size * 4 for x in jax.tree.leaves(grads))
            params, state, _ = apply(params, state, grads)
            losses.append(float(loss))
        return losses, wire_bytes

    base, bytes_f32 = train(False)
    comp, bytes_p8 = train(True)
    print(f"{'step':>5} {'f32 loss':>10} {'posit8+EF loss':>15}")
    for i in range(0, len(base), 20):
        print(f"{i:>5} {base[i]:>10.4f} {comp[i]:>15.4f}")
    print(f"final: f32={base[-1]:.4f}  posit8+EF={comp[-1]:.4f}")
    print(f"wire bytes: f32={bytes_f32:,}  posit8={bytes_p8:,} "
          f"({bytes_f32 / bytes_p8:.1f}x less)")
    assert comp[-1] < base[0] * 0.8, "compressed run failed to learn"
    assert abs(comp[-1] - base[-1]) < 0.35 * base[0], \
        "compressed diverged from exact"
    print("OK")


if __name__ == "__main__":
    main()
