"""Quickstart: the PVU vector ISA in five minutes.

Shows the five paper ops (vpadd/vpsub/vpmul/vpdiv/vpdot) on posit32
vectors, f32 conversion, the accuracy-vs-golden table, the Pallas codec
kernel, and the fused Pallas elementwise kernels (vadd/vsub/vmul/vdiv on
posit patterns — no f32 round-trip).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import (POSIT8, POSIT16, POSIT32, f32_to_posit,
                        posit_to_f32, quant_dequant, vpadd, vpdiv, vpdot,
                        vpmul, vpsub)
from repro.core import softposit_ref as golden


def main():
    print("=== 1. float -> posit -> float ===")
    x = jnp.asarray([3.14159, -0.001, 42.0, 1e6, -1e-6], jnp.float32)
    p32 = f32_to_posit(x, POSIT32)
    print("f32     :", np.asarray(x))
    print("posit32 :", [hex(int(v)) for v in np.asarray(p32)])
    print("back    :", np.asarray(posit_to_f32(p32, POSIT32)))
    print("posit16 roundtrip:",
          np.asarray(quant_dequant(x, POSIT16)))
    print("posit8  roundtrip:",
          np.asarray(quant_dequant(x, POSIT8)))

    print("\n=== 2. the five PVU ops (paper Table II ISA) ===")
    a = f32_to_posit(jnp.asarray([1.5, 2.25, -3.0, 0.125], jnp.float32),
                     POSIT32)
    b = f32_to_posit(jnp.asarray([2.0, -0.5, 0.75, 8.0], jnp.float32),
                     POSIT32)
    for name, out in [
        ("vpadd", vpadd(a, b, POSIT32)),
        ("vpsub", vpsub(a, b, POSIT32)),
        ("vpmul", vpmul(a, b, POSIT32)),
        ("vpdiv", vpdiv(a, b, POSIT32)),
    ]:
        print(f"{name}: {np.asarray(posit_to_f32(out, POSIT32))}")
    dot = vpdot(a[None, :], b[None, :], POSIT32)
    print("vpdot:", float(posit_to_f32(dot, POSIT32)[0]),
          " (exact: 3 - 1.125 - 2.25 + 1 = 0.625)")

    print("\n=== 3. exactness vs the golden model ===")
    rng = np.random.default_rng(0)
    pa = rng.integers(0, 2 ** 32, 500, dtype=np.uint32)
    pb = rng.integers(0, 2 ** 32, 500, dtype=np.uint32)
    got = np.asarray(vpmul(jnp.asarray(pa), jnp.asarray(pb), POSIT32))
    want = np.array([golden.mul(int(x), int(y), POSIT32)
                     for x, y in zip(pa, pb)], np.uint32)
    print(f"vpmul matches golden on {100 * (got == want).mean():.2f}% "
          f"of 500 random posit32 pairs")

    print("\n=== 4. Pallas codec kernel (interpret mode on CPU) ===")
    from repro.kernels import ops
    m = jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32))
    patterns = ops.quantize(m, POSIT16)
    back = ops.dequantize(patterns, POSIT16)
    err = float(jnp.abs(back - m).max() / jnp.abs(m).max())
    print(f"quantize->dequantize (64x128): storage {patterns.dtype}, "
          f"max rel err {err:.2e}")

    print("\n=== 5. fused elementwise kernels (stay in the posit domain) ===")
    m2 = jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32))
    q2 = ops.quantize(m2, POSIT16)
    # decode -> PIR add -> encode in one Pallas pass; nothing touches f32
    fused = ops.vadd(patterns, q2, POSIT16)
    roundtrip = ops.quantize(ops.dequantize(patterns, POSIT16) +
                             ops.dequantize(q2, POSIT16), POSIT16)
    print(f"fused vadd == dequant->f32 add->requant on "
          f"{100 * float((fused == roundtrip).mean()):.2f}% of 64x128 "
          f"(fused rounds once, the round-trip twice)")
    half = ops.quantize(jnp.float32(0.5), POSIT16)   # scalar broadcast
    scaled = ops.vmul(patterns, half, POSIT16)
    print(f"fused scalar vmul by 0.5: max |fused - f32 path| = "
          f"{float(jnp.abs(ops.dequantize(scaled, POSIT16) - back * 0.5).max()):.2e}")
    ratio = ops.vdiv(patterns, q2, POSIT16, mode='exact')
    nar = int((np.asarray(ratio) == POSIT16.nar_pattern).sum())
    print(f"fused exact vdiv: {nar} NaR lanes (x/0) out of {ratio.size}")


if __name__ == "__main__":
    main()
