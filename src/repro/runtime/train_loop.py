"""Train/serve step factories (pjit-ready, posit-compressed cross-pod DP).

Two train-step flavors:

* ``standard``  — loss over the ('pod','data')-sharded global batch;
  GSPMD inserts f32 gradient all-reduces.
* ``compressed`` (multi-pod + cfg.grad_compress) — the **pod-tiled**
  formulation: params are broadcast to a leading [n_pods] axis sharded
  P('pod'); vmap makes every pod's gradient *local* (no automatic
  cross-pod reduction), then the sync is explicit:

      buf   = g_pod + error_pod            (error feedback, pod-local)
      q     = posit16(buf)                 (uint16)
      q_rep = with_sharding_constraint(q, replicated-over-pod)
              -> the all-gather on the wire moves *posit patterns*
      g_hat = mean_p dequant(q_rep)

  The HLO then contains a u16 all-gather instead of an f32 all-reduce on
  the pod axis — half the cross-pod bytes (quarter with posit8), which
  the dry-run's collective analysis measures (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compress import gradient as gc
from repro.models import get_family
from repro.models.config import ModelConfig
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    *, n_pods: int = 1, compressed: bool = False,
                    total_steps: int = 10_000):
    fam = get_family(cfg)

    def loss_fn(params, batch):
        return fam.train_loss(params, batch, cfg)

    accum = max(1, cfg.grad_accum)

    def _grads_of(params, batch):
        """(loss, grads), microbatched when cfg.grad_accum > 1.

        Gradient accumulation divides activation memory by ``accum`` at
        the cost of one f32 gradient buffer (params-sized, sharded like
        the params) — the standard memory lever for big train cells.
        """
        if accum == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        micro = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)

        def mb(carry, mbatch):
            lsum, gsum = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mbatch)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (lsum + loss, gsum), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (lsum, gsum), _ = jax.lax.scan(mb, (0.0, zeros), micro)
        inv = 1.0 / accum
        return lsum * inv, jax.tree.map(lambda g: g * inv, gsum)

    if not compressed or n_pods <= 1 or not cfg.grad_compress:
        def train_step(params, opt_state, batch, step):
            loss, grads = _grads_of(params, batch)
            lr_scale = adamw.cosine_schedule(step, total=total_steps)
            params, opt_state, metrics = adamw.update(
                grads, opt_state, params, opt_cfg, lr_scale)
            return params, opt_state, {"loss": loss, **metrics}
        return train_step

    wire = cfg.grad_compress

    def train_step(params, opt_state, ef_state, batch, step):
        # tile params over the pod axis; vmap keeps gradients pod-local
        tiled = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (n_pods,) + p.shape),
            params)
        tiled = jax.lax.with_sharding_constraint(
            tiled, jax.tree.map(lambda _: P("pod"), params))

        def pod_loss(p_pod, b_pod):
            return loss_fn(p_pod, b_pod)

        losses, grads_tiled = jax.vmap(
            jax.value_and_grad(pod_loss))(tiled, batch)
        loss = losses.mean()

        # error-feedback compress (pod-local, sharded P('pod', ...))
        q, ef_state = gc.compress_with_feedback(grads_tiled, ef_state, wire)
        # the wire: force replication of the *patterns* over 'pod'
        q_rep = jax.lax.with_sharding_constraint(
            q, jax.tree.map(lambda _: P(None), params))
        g_hat = jax.tree.map(lambda t: t.mean(axis=0),
                             gc.decompress(q_rep, wire))

        lr_scale = adamw.cosine_schedule(step, total=total_steps)
        params, opt_state, metrics = adamw.update(
            g_hat, opt_state, params, opt_cfg, lr_scale)
        return params, opt_state, ef_state, {"loss": loss, **metrics}

    return train_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, token) -> (logits, cache)."""
    fam = get_family(cfg)

    def serve_step(params, cache, token):
        return fam.decode_step(params, cache, token, cfg)

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    fam = get_family(cfg)

    def prefill_step(params, batch):
        kwargs = {}
        if "frames" in batch:
            kwargs["frames"] = batch["frames"]
        if "visual" in batch:
            kwargs["visual"] = batch["visual"]
        return fam.prefill(params, batch["tokens"], cfg, **kwargs)

    return prefill_step
