"""Serving engine: preallocated posit KV caches + one-jit scan decode.

The engine is the correct-by-construction replacement for the old
prefill-then-Python-loop serving path, which was numerically wrong: the
prefill cache had no decode headroom, so every ``decode_step`` past the
first clamp-overwrote the final KV slot (``dynamic_update_slice_in_dim``
clamps out-of-range starts).  The engine:

* **preallocates** every cache to ``max_len`` up front (posit-compressed
  when ``cfg.kv_posit`` is set) and statically refuses requests that
  would not fit — decode can never run past capacity;
* runs **ring buffers** for sliding-window caches (capacity = window,
  writes at ``pos % window``, rotation-aware masks in
  ``decode_attention``);
* decodes with a single ``lax.scan`` — one compiled call per
  ``max_new_tokens``, no per-token Python dispatch;
* **batches ragged prompts** (transformer family): prompts are
  left-padded to a common length, each row carries its own length, RoPE
  positions and attention masks are per-row — the seed of continuous
  batching;
* samples greedily or with temperature, batched, from one PRNG stream;
* optionally runs the **paged** cache layout (``paged=True``,
  transformer family): a block arena + per-row block tables replaces
  the dense ``batch x max_len`` preallocation, rows allocate blocks
  from a host-side ``kvcache.BlockPool`` as they grow, and the token
  streams are byte-identical to the dense layout's.

Usage::

    from repro.runtime.engine import Engine
    eng = Engine(cfg, params, max_len=256, temperature=0.0, seed=0)
    res = eng.generate([[5, 3, 9], [7, 2, 4, 4, 1]], max_new_tokens=32)
    res.tokens          # (2, 32) int32
    res.prompt_lens     # [3, 5]
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.compress import kvcache as kvc
from repro.models import get_family
from repro.models.config import ModelConfig


def sample_token(logits, key, temperature: float):
    """(B,V) f32 logits -> ((B,) int32 token, advanced key).

    ``temperature`` is static: 0 is greedy argmax (consumes no
    randomness), > 0 is softmax sampling at that temperature.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
    key, sub = jax.random.split(key)
    tok = jax.random.categorical(sub, logits / temperature, axis=-1)
    return tok.astype(jnp.int32), key


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray        # (B, max_new_tokens) int32
    prompt_lens: np.ndarray   # (B,) int32 per-slot prompt lengths
    prefill_logits: np.ndarray  # (B, V) f32 logits after the prompt
    cache: Any                # final engine-shaped cache pytree


class Engine:
    """Batched serving engine over the four-family model protocol."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 pad_id: int = 0, paged: bool = False,
                 block_size: int = 16, n_blocks: int = 0,
                 sanitize: bool = False):
        """``paged=True`` swaps the dense preallocated cache for the
        block-table layout (transformer family only): prefill allocates
        arena blocks per row from a host-side ``BlockPool`` free list
        instead of reserving ``batch x max_len`` slots up front.
        ``n_blocks`` sizes the shared arena (0 = worst case, one full
        table per row — no memory win, but never out of blocks).
        ``sanitize=True`` arms the arena sanitizer: pools are created
        with ``BlockPool(sanitize=True)`` (double-free/use-after-free/
        COW-skip detection) and reclaimed blocks are poisoned on device
        via :meth:`poison_blocks` so stale table entries detonate."""
        self.cfg = cfg
        self.params = params
        self.fam = get_family(cfg)
        self.max_len = int(max_len)
        self.temperature = float(temperature)
        self.pad_id = int(pad_id)
        self.paged = bool(paged)
        self.block_size = int(block_size)
        self.n_blocks = int(n_blocks)
        self.sanitize = bool(sanitize)
        if self.paged:
            if cfg.family != "transformer":
                raise ValueError(
                    "paged KV caches need the transformer family's "
                    f"per-row decode positions (got {cfg.family!r})")
            if self.block_size < 1:
                raise ValueError(
                    f"block_size must be >= 1, got {self.block_size}")
            from repro.models import layers as L
            from repro.models import transformer as T
            self.table_width = T.paged_table_width(
                cfg, self.block_size, self.max_len)
            self.window_lane = L.paged_is_window_lane(
                T._paged_window(cfg), self.block_size, self.table_width)
        self.pool = None               # BlockPool of the last paged prefill
        self._key = jax.random.PRNGKey(seed)
        self._prefill_jit = {}
        self._decode_jit = {}

    # ------------------------------------------------------------------
    # prompt packing
    # ------------------------------------------------------------------

    def pack_prompts(self, prompts):
        """list-of-token-lists (or a 2-D array) -> left-padded (B,S)
        int32 tokens + (B,) int32 lens."""
        arr = np.asarray(prompts, dtype=object) \
            if not isinstance(prompts, (np.ndarray, jnp.ndarray)) else prompts
        if isinstance(arr, (np.ndarray, jnp.ndarray)) and arr.ndim == 2 \
                and arr.dtype != object:
            tokens = np.asarray(arr, np.int32)
            lens = np.full((tokens.shape[0],), tokens.shape[1], np.int32)
            return tokens, lens
        lens = np.asarray([len(p) for p in prompts], np.int32)
        s = int(lens.max())
        tokens = np.full((len(prompts), s), self.pad_id, np.int32)
        for i, p in enumerate(prompts):                   # left-pad
            tokens[i, s - len(p):] = np.asarray(p, np.int32)
        return tokens, lens

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------

    def _prefill_fn(self, ragged: bool, kw_names: tuple,
                    n_blocks: int = 0):
        cfg, fam, ml, bs = self.cfg, self.fam, self.max_len, \
            self.block_size

        def run(params, tokens, lens, *kw_vals):
            kw = dict(zip(kw_names, kw_vals))    # tables ride past the zip
            if n_blocks:
                kw.update(block_tables=kw_vals[-1], block_size=bs,
                          n_blocks=n_blocks)
            if ragged:
                return fam.prefill(params, tokens, cfg, max_len=ml,
                                   prompt_lens=lens, **kw)
            return fam.prefill(params, tokens, cfg, max_len=ml, **kw)

        return jax.jit(run)

    def _row_blocks_needed(self, prompt_len: int, reserve: int) -> int:
        """Blocks covering a row's prompt plus ``reserve`` decode
        writes (window rows hold the full bounded ring)."""
        if self.window_lane:
            return self.table_width
        need = min(prompt_len + reserve, self.max_len)
        return -(-need // self.block_size)

    def _alloc_tables(self, lens, reserve: int, n_blocks: int,
                      pool=None):
        """Host-side block allocation for a prompt batch: returns the
        (B, W) int32 table (sentinel = n_blocks in unassigned entries)
        and the pool it drew from."""
        pool = pool or kvc.BlockPool(n_blocks, sanitize=self.sanitize)
        tables = np.full((len(lens), self.table_width), n_blocks,
                         np.int32)
        for row, pl in enumerate(lens):
            need = self._row_blocks_needed(int(pl), reserve)
            tables[row, :need] = pool.alloc(need)
        return tables, pool

    def prefill(self, prompts, *, frames=None, visual=None,
                reserve_tokens: int = 0, paged=None):
        """Run the (possibly ragged) prompt batch; returns
        (cache, last-position logits (B,V), lens (B,)).

        On a paged engine, each row gets arena blocks covering its
        prompt plus ``reserve_tokens`` decode writes (``generate``
        reserves its whole budget up front so the one-scan decode never
        needs new blocks); ``paged=False`` forces the dense linear
        layout — the scheduler's admission path prefills rows linearly
        and packs them into the shared pool arena itself.
        """
        use_paged = self.paged if paged is None else bool(paged)
        if use_paged and not self.paged:
            raise ValueError(
                "prefill(paged=True) needs an engine constructed with "
                "Engine(..., paged=True): only that sizes the block "
                "tables and arena")
        tokens, lens = self.pack_prompts(prompts)
        b, s = tokens.shape
        if s > self.max_len:
            raise ValueError(
                f"padded prompt length {s} exceeds engine max_len "
                f"{self.max_len}")
        ragged = bool((lens != lens[0]).any())
        if ragged and self.cfg.family != "transformer":
            raise ValueError(
                "ragged prompt batches are only supported for the "
                f"transformer family (got family={self.cfg.family!r}); "
                "pad or bucket the prompts")
        if ragged and visual is not None:
            raise ValueError(
                "ragged prompt batches cannot carry a visual prefix: "
                "patch embeddings are prepended at the sequence front, "
                "which is where left-padding lives; pad the prompts to a "
                "common length instead")
        kw = {k: v for k, v in (("frames", frames), ("visual", visual))
              if v is not None}
        args = [kw[k] for k in sorted(kw)]
        nb = 0
        if use_paged:
            nb = self.n_blocks or b * self.table_width
            tables, self.pool = self._alloc_tables(
                lens, int(reserve_tokens), nb)
            args.append(jnp.asarray(tables))
        key = (ragged, tuple(sorted(kw)), nb)
        if key not in self._prefill_jit:
            self._prefill_jit[key] = self._prefill_fn(
                ragged, tuple(sorted(kw)), n_blocks=nb)
        cache, logits = self._prefill_jit[key](
            self.params, jnp.asarray(tokens), jnp.asarray(lens), *args)
        return cache, logits, lens

    # ------------------------------------------------------------------
    # prefix sharing: suffix prefill + COW block copies (paged only)
    # ------------------------------------------------------------------

    def _suffix_fn(self, plen: int, prefix_len: int):
        from repro.models import layers as L
        from repro.models import transformer as T
        cfg = self.cfg
        win = T._paged_window(cfg)
        keys = ("c_kv", "k_rope") if cfg.mla else ("k", "v")

        def run(params, cache, tokens, gather_ids, table):
            prefix = {k: L.paged_gather_layers(cache[k], gather_ids)
                      for k in keys}
            kvs, logits = T.prefill_suffix(params, tokens, cfg, prefix,
                                           prefix_len)
            lens = jnp.full((1,), plen, jnp.int32)
            out = dict(cache)
            for k in keys:
                out[k] = L.paged_pack_range(
                    cache[k], kvs[k], table[None], prefix_len, lens,
                    window=win)
            return out, logits

        return jax.jit(run)

    def prefill_suffix(self, prompt, cache, gather_ids, write_table,
                       prefix_len: int):
        """Prefix-sharing admission: prefill ONLY ``prompt[prefix_len:]``
        of a batch-1 request whose leading tokens are resident in shared
        arena blocks, writing the suffix KV straight into ``cache``'s
        arena leaves.

        ``gather_ids``: (Wp,) physical ids of the borrowed prefix blocks
        (``Wp * block_size >= prefix_len``); ``write_table``: the row's
        full (W,) table with every still-borrowed entry replaced by the
        sentinel so shared blocks can never take a write through this
        path.  Returns ``(cache, logits)`` with updated content leaves
        and the (1, V) last-position logits.  Jit-specialized per
        (prompt length, prefix length) pair, like admission prefill is
        per prompt length.
        """
        if not self.paged:
            raise ValueError("prefill_suffix needs Engine(paged=True)")
        plen = len(prompt)
        prefix_len = int(prefix_len)
        if not 0 < prefix_len <= plen - 2:
            raise ValueError(
                f"prefix_len {prefix_len} outside [1, plen-2={plen - 2}]"
                " (>= 2 suffix tokens keep the matmul shapes off the "
                "bitwise-divergent length-1 path)")
        toks = jnp.asarray(prompt, jnp.int32)[None, prefix_len:]
        key = ("suffix", plen, prefix_len, len(gather_ids))
        if key not in self._prefill_jit:
            self._prefill_jit[key] = self._suffix_fn(plen, prefix_len)
        return self._prefill_jit[key](
            self.params, cache, toks,
            jnp.asarray(gather_ids, jnp.int32),
            jnp.asarray(write_table, jnp.int32))

    def copy_blocks(self, cache, src_ids, dst_ids):
        """COW device half: duplicate arena blocks ``src_ids -> dst_ids``
        across every content leaf (posit patterns move verbatim, no
        dequantize round-trip).  Jit-specialized per copy count."""
        from repro.models import layers as L
        keys = ("c_kv", "k_rope") if self.cfg.mla else ("k", "v")
        key = ("copy", len(src_ids))
        if key not in self._decode_jit:
            def run(cache, src, dst):
                out = dict(cache)
                for k in keys:
                    out[k] = L.paged_copy_blocks(cache[k], src, dst)
                return out
            self._decode_jit[key] = jax.jit(run)
        return self._decode_jit[key](
            cache, jnp.asarray(src_ids, jnp.int32),
            jnp.asarray(dst_ids, jnp.int32))

    def poison_blocks(self, cache, ids):
        """Sanitizer device half: overwrite reclaimed arena blocks with
        the loud-but-finite poison pattern (``layers.paged_poison_blocks``)
        across every content leaf.  Jit-specialized per block count; a
        stale table entry pointing at a poisoned block corrupts logits
        visibly instead of silently serving freed KV."""
        if not ids:
            return cache
        from repro.models import layers as L
        keys = ("c_kv", "k_rope") if self.cfg.mla else ("k", "v")
        key = ("poison", len(ids))
        if key not in self._decode_jit:
            def run(cache, ids):
                out = dict(cache)
                for k in keys:
                    out[k] = L.paged_poison_blocks(cache[k], ids)
                return out
            self._decode_jit[key] = jax.jit(run)
        return self._decode_jit[key](cache, jnp.asarray(ids, jnp.int32))

    # ------------------------------------------------------------------
    # decode: one lax.scan == one compiled call for the whole generation
    # ------------------------------------------------------------------

    def _decode_fn(self, n_steps: int):
        cfg, fam, temp = self.cfg, self.fam, self.temperature

        def run(params, cache, logits, key):
            tok0, key = sample_token(logits, key, temp)

            def step(carry, _):
                cache, tok, key = carry
                logits, cache = fam.decode_step(params, cache, tok, cfg)
                nxt, key = sample_token(logits, key, temp)
                return (cache, nxt, key), nxt

            (cache, _, key), toks = lax.scan(
                step, (cache, tok0, key), length=n_steps - 1)
            out = jnp.concatenate([tok0[None], toks], axis=0)  # (n,B)
            return cache, out.T, key

        return jax.jit(run)

    def _chunk_fn(self, n_steps: int):
        """Fixed-size decode chunk: ``n_steps`` masked decode steps in ONE
        ``lax.scan`` — the continuous-batching quantum.  Jitted once per
        chunk size, so admissions/retirements between chunks never
        recompile anything."""
        cfg, fam, temp = self.cfg, self.fam, self.temperature
        masked = cfg.family in ("transformer", "hymba")

        def run(params, cache, tok, key, active):
            def step(carry, _):
                cache, tok, key = carry
                if masked:
                    logits, cache = fam.decode_step(params, cache, tok,
                                                    cfg, active=active)
                else:
                    logits, cache = fam.decode_step(params, cache, tok, cfg)
                nxt, key = sample_token(logits, key, temp)
                return (cache, nxt, key), nxt

            (cache, _, key), toks = lax.scan(
                step, (cache, tok, key), length=n_steps)
            return cache, toks.T, key                     # (B, n_steps)

        return jax.jit(run)

    def decode_chunk(self, cache, tokens, n_steps: int, *, active=None):
        """Advance every slot by ``n_steps`` decode steps in one compiled
        dispatch; returns (cache, (B, n_steps) int32 sampled tokens).

        ``tokens``: (B,) the last sampled token per row (admission seeds
        this from the prefill logits).  ``active``: (B,) bool — inactive
        (empty / already-finished) rows still run through the batched
        model but their ``lens`` metadata stays frozen and their sampled
        tokens are garbage the scheduler discards.

        Raises if the chunk would run the write frontier past ``max_len``
        — the frontier is concrete between dispatches, so the guard is
        free, and without it the traced in-chunk writes would be silently
        DROPPED (the no-clamp guarantee), corrupting the tokens.  Callers
        (the scheduler) compact the cache first instead.
        """
        from repro.core.tracing import is_tracer
        if "block_tables" in cache:
            lens = cache["lens"]
            if not is_tracer(lens):
                act = np.ones((np.asarray(lens).shape[0],), bool) \
                    if active is None else np.asarray(active, bool)
                if act.any():
                    hi = int(np.asarray(lens)[act].max())
                    if hi + int(n_steps) > self.max_len:
                        raise ValueError(
                            f"decode_chunk: paged row frontier {hi} + "
                            f"{int(n_steps)} steps exceeds engine "
                            f"max_len {self.max_len}; retire rows first")
        elif not is_tracer(cache["len"]) and \
                int(cache["len"]) + int(n_steps) > self.max_len:
            raise ValueError(
                f"decode_chunk: frontier {int(cache['len'])} + "
                f"{int(n_steps)} steps exceeds engine max_len "
                f"{self.max_len}; compact the cache (kvcache.compact) "
                "or retire rows first")
        tokens = jnp.asarray(tokens, jnp.int32)
        b = tokens.shape[0]
        active = jnp.ones((b,), bool) if active is None \
            else jnp.asarray(active, bool)
        key = ("chunk", int(n_steps))
        if key not in self._decode_jit:
            self._decode_jit[key] = self._chunk_fn(int(n_steps))
        cache, toks, self._key = self._decode_jit[key](
            self.params, cache, tokens, self._key, active)
        return cache, toks

    def _check_fits(self, padded_len: int, max_new_tokens: int):
        need = padded_len + max_new_tokens - 1        # last token not cached
        if need > self.max_len:
            raise ValueError(
                f"prompt ({padded_len}) + {max_new_tokens} new tokens "
                f"needs {need} cache slots > engine max_len {self.max_len}")

    def generate(self, prompts, max_new_tokens: int, *, frames=None,
                 visual=None) -> GenerationResult:
        """Prefill + scan-decode ``max_new_tokens`` tokens in ONE compiled
        decode call.  Raises up front if the request cannot fit in the
        preallocated ``max_len`` — out-of-capacity writes never clamp."""
        tokens, _ = self.pack_prompts(prompts)
        self._check_fits(tokens.shape[1], max_new_tokens)
        cache, logits, lens = self.prefill(
            prompts, frames=frames, visual=visual,
            reserve_tokens=max_new_tokens - 1)
        if max_new_tokens not in self._decode_jit:
            self._decode_jit[max_new_tokens] = self._decode_fn(
                max_new_tokens)
        cache, toks, self._key = self._decode_jit[max_new_tokens](
            self.params, cache, logits, self._key)
        return GenerationResult(tokens=np.asarray(toks),
                                prompt_lens=np.asarray(lens),
                                prefill_logits=np.asarray(logits),
                                cache=cache)

    def generate_stepwise(self, prompts, max_new_tokens: int, *,
                          frames=None, visual=None) -> GenerationResult:
        """Reference path: same sampling, but one jitted decode_step per
        token (Python-loop dispatch).  Produces tokens identical to
        ``generate`` — kept for tests and dispatch-overhead benchmarks."""
        tokens, _ = self.pack_prompts(prompts)
        self._check_fits(tokens.shape[1], max_new_tokens)
        cache, logits, lens = self.prefill(
            prompts, frames=frames, visual=visual,
            reserve_tokens=max_new_tokens - 1)
        if "step" not in self._decode_jit:
            fam, cfg = self.fam, self.cfg
            self._decode_jit["step"] = jax.jit(
                lambda p, c, t: fam.decode_step(p, c, t, cfg))
        key = self._key
        tok, key = sample_token(logits, key, self.temperature)
        outs = [tok]
        for _ in range(max_new_tokens - 1):
            step_logits, cache = self._decode_jit["step"](
                self.params, cache, tok)
            tok, key = sample_token(step_logits, key, self.temperature)
            outs.append(tok)
        self._key = key
        return GenerationResult(
            tokens=np.stack([np.asarray(t) for t in outs], axis=1),
            prompt_lens=np.asarray(lens),
            prefill_logits=np.asarray(logits), cache=cache)
