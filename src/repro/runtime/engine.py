"""Serving engine: preallocated posit KV caches + one-jit scan decode.

The engine is the correct-by-construction replacement for the old
prefill-then-Python-loop serving path, which was numerically wrong: the
prefill cache had no decode headroom, so every ``decode_step`` past the
first clamp-overwrote the final KV slot (``dynamic_update_slice_in_dim``
clamps out-of-range starts).  The engine:

* **preallocates** every cache to ``max_len`` up front (posit-compressed
  when ``cfg.kv_posit`` is set) and statically refuses requests that
  would not fit — decode can never run past capacity;
* runs **ring buffers** for sliding-window caches (capacity = window,
  writes at ``pos % window``, rotation-aware masks in
  ``decode_attention``);
* decodes with a single ``lax.scan`` — one compiled call per
  ``max_new_tokens``, no per-token Python dispatch;
* **batches ragged prompts** (transformer family): prompts are
  left-padded to a common length, each row carries its own length, RoPE
  positions and attention masks are per-row — the seed of continuous
  batching;
* samples greedily or with temperature, batched, from one PRNG stream;
* optionally runs the **paged** cache layout (``paged=True``,
  transformer family): a block arena + per-row block tables replaces
  the dense ``batch x max_len`` preallocation, rows allocate blocks
  from a host-side ``kvcache.BlockPool`` as they grow, and the token
  streams are byte-identical to the dense layout's;
* serves **chunked prefill** through :meth:`Engine.mixed_step`: fixed
  ``C``-token prompt chunks and masked decode steps share ONE compiled
  dispatch shape keyed ``("mixed", C, n_steps)``, so prompt length
  never jit-specializes anything (``n_compiles`` stays flat).

Usage::

    from repro.runtime.engine import Engine
    eng = Engine(cfg, params, max_len=256, temperature=0.0, seed=0)
    res = eng.generate([[5, 3, 9], [7, 2, 4, 4, 1]], max_new_tokens=32)
    res.tokens          # (2, 32) int32
    res.prompt_lens     # [3, 5]
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.compress import kvcache as kvc
from repro.models import get_family
from repro.models.config import ModelConfig
from repro.runtime import sharding as shd


def sample_token(logits, key, temperature: float):
    """(B,V) f32 logits -> ((B,) int32 token, advanced key).

    ``temperature`` is static: 0 is greedy argmax (consumes no
    randomness), > 0 is softmax sampling at that temperature.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
    key, sub = jax.random.split(key)
    tok = jax.random.categorical(sub, logits / temperature, axis=-1)
    return tok.astype(jnp.int32), key


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray        # (B, max_new_tokens) int32
    prompt_lens: np.ndarray   # (B,) int32 per-slot prompt lengths
    prefill_logits: np.ndarray  # (B, V) f32 logits after the prompt
    cache: Any                # final engine-shaped cache pytree


class Engine:
    """Batched serving engine over the four-family model protocol."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 pad_id: int = 0, paged: bool = False,
                 block_size: int = 16, n_blocks: int = 0,
                 sanitize: bool = False, decode_kernel: str = None,
                 mesh=None):
        """``paged=True`` swaps the dense preallocated cache for the
        block-table layout (transformer family only): prefill allocates
        arena blocks per row from a host-side ``BlockPool`` free list
        instead of reserving ``batch x max_len`` slots up front.
        ``n_blocks`` sizes the shared arena (0 = worst case, one full
        table per row — no memory win, but never out of blocks).
        ``sanitize=True`` arms the arena sanitizer: pools are created
        with ``BlockPool(sanitize=True)`` (double-free/use-after-free/
        COW-skip detection) and reclaimed blocks are poisoned on device
        via :meth:`poison_blocks` so stale table entries detonate.
        ``decode_kernel`` selects the paged decode-attention path:
        ``'gather'`` (jnp reference) or ``'fused'`` (the Pallas
        block-table-walk kernel, ``kernels/posit_paged_attn.py``);
        it threads through ``cfg.paged_attn_kernel`` so every jitted
        decode program closes over the choice.
        ``mesh`` (a ``jax.sharding.Mesh`` with a 'model' axis, e.g. from
        ``launch.mesh.make_host_mesh``) serves tensor-parallel: the
        weights are placed by the ``runtime/sharding.py`` rule table,
        paged pool caches get head-sharded arenas via
        :meth:`shard_cache`, and every dispatch runs inside the mesh
        context so the model-side sharding constraints resolve.  Token
        streams are identical to the mesh-less engine's."""
        if decode_kernel is not None:
            if decode_kernel not in ("gather", "fused"):
                raise ValueError(
                    f"decode_kernel must be 'gather' or 'fused', got "
                    f"{decode_kernel!r}")
            if not paged:
                raise ValueError(
                    "decode_kernel selects the PAGED decode attention "
                    "path; construct the engine with paged=True")
            cfg = dataclasses.replace(cfg, paged_attn_kernel=decode_kernel)
        self.cfg = cfg
        self.params = params
        self.fam = get_family(cfg)
        self.max_len = int(max_len)
        self.temperature = float(temperature)
        self.pad_id = int(pad_id)
        self.paged = bool(paged)
        self.block_size = int(block_size)
        self.n_blocks = int(n_blocks)
        self.sanitize = bool(sanitize)
        if self.paged:
            if cfg.family != "transformer":
                raise ValueError(
                    "paged KV caches need the transformer family's "
                    f"per-row decode positions (got {cfg.family!r})")
            if self.block_size < 1:
                raise ValueError(
                    f"block_size must be >= 1, got {self.block_size}")
            from repro.models import layers as L
            from repro.models import transformer as T
            self.table_width = T.paged_table_width(
                cfg, self.block_size, self.max_len)
            self.window_lane = L.paged_is_window_lane(
                T._paged_window(cfg), self.block_size, self.table_width)
        self.mesh = mesh
        if mesh is not None:
            # one-time placement: TP rules from the sharding table;
            # every later dispatch sees committed sharded weights and
            # compiles SPMD against them
            self.params = jax.device_put(
                params, shd.param_shardings(params, mesh))
        self.pool = None               # BlockPool of the last paged prefill
        self._key = jax.random.PRNGKey(seed)
        self._prefill_jit = {}
        self._decode_jit = {}

    def shard_cache(self, cache):
        """Place a paged pool cache on the engine mesh: dense arena
        leaves head-sharded over 'model', MLA latents and metadata
        replicated (``sharding.paged_cache_specs``).  Identity without
        a mesh; a no-op for leaves already canonically placed — also
        used between dispatches to keep the cache's shardings stable so
        the serving loop never recompiles on a sharding change."""
        if self.mesh is None:
            return cache
        return jax.device_put(
            cache, shd.paged_cache_shardings(cache, self.mesh, self.cfg))

    def _dispatch(self, fn, *args):
        """Invoke a jitted callable inside the engine mesh context when
        one is set, so ``PartitionSpec`` sharding constraints in the
        model code resolve (they no-op without a mesh)."""
        if self.mesh is None:
            return fn(*args)
        with shd.set_mesh(self.mesh):
            return fn(*args)

    @property
    def n_compiles(self) -> int:
        """Distinct lowered programs this engine has compiled: the sum
        of every cached jit callable's trace-cache size, so per-shape
        retraces INSIDE one callable count too (the unchunked prefill
        path retraces per padded prompt length without ever missing the
        engine's own jit cache).  The scheduler surfaces it in
        ``Scheduler.stats``; chunked-prefill mode pins it flat after
        warmup no matter how ragged the admitted prompt lengths are
        (tests/test_scheduler.py)."""
        n = 0
        for store in (self._prefill_jit, self._decode_jit):
            for fn in store.values():
                sz = getattr(fn, "_cache_size", None)
                n += sz() if callable(sz) else 1
        return n

    def _get_jit(self, store: dict, key, build):
        """Jit-cache lookup: a miss builds one new jitted callable
        (whose compilations ``n_compiles`` then tracks)."""
        if key not in store:
            store[key] = build()
        return store[key]

    # ------------------------------------------------------------------
    # prompt packing
    # ------------------------------------------------------------------

    def pack_prompts(self, prompts):
        """list-of-token-lists (or a 2-D array) -> left-padded (B,S)
        int32 tokens + (B,) int32 lens."""
        arr = np.asarray(prompts, dtype=object) \
            if not isinstance(prompts, (np.ndarray, jnp.ndarray)) else prompts
        if isinstance(arr, (np.ndarray, jnp.ndarray)) and arr.ndim == 2 \
                and arr.dtype != object:
            tokens = np.asarray(arr, np.int32)
            lens = np.full((tokens.shape[0],), tokens.shape[1], np.int32)
            return tokens, lens
        lens = np.asarray([len(p) for p in prompts], np.int32)
        s = int(lens.max())
        tokens = np.full((len(prompts), s), self.pad_id, np.int32)
        for i, p in enumerate(prompts):                   # left-pad
            tokens[i, s - len(p):] = np.asarray(p, np.int32)
        return tokens, lens

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------

    def _prefill_fn(self, ragged: bool, kw_names: tuple,
                    n_blocks: int = 0):
        cfg, fam, ml, bs = self.cfg, self.fam, self.max_len, \
            self.block_size

        def run(params, tokens, lens, *kw_vals):
            kw = dict(zip(kw_names, kw_vals))    # tables ride past the zip
            if n_blocks:
                kw.update(block_tables=kw_vals[-1], block_size=bs,
                          n_blocks=n_blocks)
            if ragged:
                return fam.prefill(params, tokens, cfg, max_len=ml,
                                   prompt_lens=lens, **kw)
            return fam.prefill(params, tokens, cfg, max_len=ml, **kw)

        return jax.jit(run)

    def _row_blocks_needed(self, prompt_len: int, reserve: int) -> int:
        """Blocks covering a row's prompt plus ``reserve`` decode
        writes (window rows hold the full bounded ring)."""
        if self.window_lane:
            return self.table_width
        need = min(prompt_len + reserve, self.max_len)
        return -(-need // self.block_size)

    def _alloc_tables(self, lens, reserve: int, n_blocks: int,
                      pool=None):
        """Host-side block allocation for a prompt batch: returns the
        (B, W) int32 table (sentinel = n_blocks in unassigned entries)
        and the pool it drew from."""
        pool = pool or kvc.BlockPool(n_blocks, sanitize=self.sanitize)
        tables = np.full((len(lens), self.table_width), n_blocks,
                         np.int32)
        for row, pl in enumerate(lens):
            need = self._row_blocks_needed(int(pl), reserve)
            tables[row, :need] = pool.alloc(need)
        return tables, pool

    def prefill(self, prompts, *, frames=None, visual=None,
                reserve_tokens: int = 0, paged=None):
        """Run the (possibly ragged) prompt batch; returns
        (cache, last-position logits (B,V), lens (B,)).

        On a paged engine, each row gets arena blocks covering its
        prompt plus ``reserve_tokens`` decode writes (``generate``
        reserves its whole budget up front so the one-scan decode never
        needs new blocks); ``paged=False`` forces the dense linear
        layout — the scheduler's admission path prefills rows linearly
        and packs them into the shared pool arena itself.
        """
        use_paged = self.paged if paged is None else bool(paged)
        if use_paged and not self.paged:
            raise ValueError(
                "prefill(paged=True) needs an engine constructed with "
                "Engine(..., paged=True): only that sizes the block "
                "tables and arena")
        tokens, lens = self.pack_prompts(prompts)
        b, s = tokens.shape
        if s > self.max_len:
            raise ValueError(
                f"padded prompt length {s} exceeds engine max_len "
                f"{self.max_len}")
        ragged = bool((lens != lens[0]).any())
        if ragged and self.cfg.family != "transformer":
            raise ValueError(
                "ragged prompt batches are only supported for the "
                f"transformer family (got family={self.cfg.family!r}); "
                "pad or bucket the prompts")
        if ragged and visual is not None:
            raise ValueError(
                "ragged prompt batches cannot carry a visual prefix: "
                "patch embeddings are prepended at the sequence front, "
                "which is where left-padding lives; pad the prompts to a "
                "common length instead")
        kw = {k: v for k, v in (("frames", frames), ("visual", visual))
              if v is not None}
        args = [kw[k] for k in sorted(kw)]
        nb = 0
        if use_paged:
            nb = self.n_blocks or b * self.table_width
            tables, self.pool = self._alloc_tables(
                lens, int(reserve_tokens), nb)
            args.append(jnp.asarray(tables))
        key = (ragged, tuple(sorted(kw)), nb)
        fn = self._get_jit(self._prefill_jit, key,
                           lambda: self._prefill_fn(
                               ragged, tuple(sorted(kw)), n_blocks=nb))
        cache, logits = self._dispatch(
            fn, self.params, jnp.asarray(tokens), jnp.asarray(lens), *args)
        if use_paged:
            cache = self.shard_cache(cache)
        return cache, logits, lens

    # ------------------------------------------------------------------
    # mixed dispatch: prefill chunks + decode steps, one compiled shape
    # ------------------------------------------------------------------

    def _mixed_fn(self, n_steps: int):
        """One compiled program = one prefill chunk over every row
        (rows with ``n_valid == 0`` are exact no-ops) followed by
        ``n_steps`` masked decode steps.  The shapes depend only on
        (batch, chunk width, n_steps) — never on any prompt length — so
        a scheduler running in chunked mode compiles this ONCE and
        serves every request with it."""
        from repro.models import transformer as T
        cfg, fam, temp = self.cfg, self.fam, self.temperature
        vw = -(-self.max_len // self.block_size)

        def run(params, cache, chunk_tokens, n_valid, tok, key,
                decode_active, write_tables):
            cache, chunk_logits = T.prefill_chunk(
                params, cache, chunk_tokens, cfg, n_valid,
                virtual_width=vw, write_tables=write_tables)

            def step(carry, _):
                cache, tok, key = carry
                logits, cache = fam.decode_step(params, cache, tok, cfg,
                                                active=decode_active)
                nxt, key = sample_token(logits, key, temp)
                return (cache, nxt, key), nxt

            (cache, _, key), toks = lax.scan(
                step, (cache, tok, key), length=n_steps)
            return cache, chunk_logits, toks.T, key

        return jax.jit(run)

    def mixed_step(self, cache, chunk_tokens, n_valid, tokens,
                   n_steps: int, *, decode_active=None,
                   write_tables=None):
        """Advance prefilling AND decoding rows in one compiled dispatch
        (paged transformer engines only).

        Phase 1 runs ``T.prefill_chunk``: row ``b`` appends
        ``chunk_tokens[b, :n_valid[b]]`` at positions ``lens[b]...`` of
        its paged cache (``n_valid[b] == 0`` rows — decoding or idle —
        are untouched).  Phase 2 runs ``n_steps`` masked decode steps
        for rows with ``decode_active`` set, fed by ``tokens`` (the last
        sampled token per row; garbage for non-decoding rows, whose
        writes are dropped).  ``write_tables``: per-row tables with
        borrowed (shared) entries sentineled so prefix blocks never take
        a write — defaults to ``cache["block_tables"]``.

        Returns ``(cache, chunk_logits (B, V), toks (B, n_steps))``:
        ``chunk_logits[b]`` is the logits at row ``b``'s last valid
        chunk position (sample tok0 from it when the chunk completes the
        prompt); ``toks`` are the decode samples (discard inactive
        rows').  Jit key is ``("mixed", C, n_steps)`` — compiled once
        per (chunk width, decode quantum), independent of every prompt
        length in flight.
        """
        if not self.paged:
            raise ValueError("mixed_step needs Engine(paged=True)")
        from repro.core.tracing import is_tracer
        chunk_tokens = jnp.asarray(chunk_tokens, jnp.int32)
        b, c = chunk_tokens.shape
        nv = np.asarray(n_valid, np.int32)
        act = np.zeros((b,), bool) if decode_active is None \
            else np.asarray(decode_active, bool)
        lens = cache["lens"]
        if not is_tracer(lens):
            lens_np = np.asarray(lens)
            if (nv > 0).any():
                hi = int((lens_np + nv)[nv > 0].max())
                if hi > self.max_len:
                    raise ValueError(
                        f"mixed_step: prefill chunk frontier {hi} "
                        f"exceeds engine max_len {self.max_len}")
            if act.any():
                hi = int(lens_np[act].max())
                if hi + int(n_steps) > self.max_len:
                    raise ValueError(
                        f"mixed_step: decode frontier {hi} + "
                        f"{int(n_steps)} steps exceeds engine max_len "
                        f"{self.max_len}; retire rows first")
        wt = cache["block_tables"] if write_tables is None \
            else jnp.asarray(write_tables, jnp.int32)
        key = ("mixed", int(c), int(n_steps))
        fn = self._get_jit(self._decode_jit, key,
                           lambda: self._mixed_fn(int(n_steps)))
        cache, chunk_logits, toks, self._key = self._dispatch(
            fn, self.params, cache, chunk_tokens, jnp.asarray(nv),
            jnp.asarray(tokens, jnp.int32), self._key,
            jnp.asarray(act), wt)
        return self.shard_cache(cache), chunk_logits, toks

    # ------------------------------------------------------------------
    # prefix sharing: COW block copies + sanitizer poison (paged only)
    # ------------------------------------------------------------------

    def copy_blocks(self, cache, src_ids, dst_ids):
        """COW device half: duplicate arena blocks ``src_ids -> dst_ids``
        across every content leaf (posit patterns move verbatim, no
        dequantize round-trip).  Jit-specialized per copy count."""
        from repro.models import layers as L
        keys = ("c_kv", "k_rope") if self.cfg.mla else ("k", "v")

        def build():
            def run(cache, src, dst):
                out = dict(cache)
                for k in keys:
                    out[k] = L.paged_copy_blocks(cache[k], src, dst)
                return out
            return jax.jit(run)

        fn = self._get_jit(self._decode_jit, ("copy", len(src_ids)),
                           build)
        return self.shard_cache(self._dispatch(
            fn, cache, jnp.asarray(src_ids, jnp.int32),
            jnp.asarray(dst_ids, jnp.int32)))

    def poison_blocks(self, cache, ids):
        """Sanitizer device half: overwrite reclaimed arena blocks with
        the loud-but-finite poison pattern (``layers.paged_poison_blocks``)
        across every content leaf.  Jit-specialized per block count; a
        stale table entry pointing at a poisoned block corrupts logits
        visibly instead of silently serving freed KV."""
        if not ids:
            return cache
        from repro.models import layers as L
        keys = ("c_kv", "k_rope") if self.cfg.mla else ("k", "v")

        def build():
            def run(cache, ids):
                out = dict(cache)
                for k in keys:
                    out[k] = L.paged_poison_blocks(cache[k], ids)
                return out
            return jax.jit(run)

        fn = self._get_jit(self._decode_jit, ("poison", len(ids)), build)
        return self.shard_cache(self._dispatch(
            fn, cache, jnp.asarray(ids, jnp.int32)))

    # ------------------------------------------------------------------
    # decode: one lax.scan == one compiled call for the whole generation
    # ------------------------------------------------------------------

    def _decode_fn(self, n_steps: int):
        cfg, fam, temp = self.cfg, self.fam, self.temperature

        def run(params, cache, logits, key):
            tok0, key = sample_token(logits, key, temp)

            def step(carry, _):
                cache, tok, key = carry
                logits, cache = fam.decode_step(params, cache, tok, cfg)
                nxt, key = sample_token(logits, key, temp)
                return (cache, nxt, key), nxt

            (cache, _, key), toks = lax.scan(
                step, (cache, tok0, key), length=n_steps - 1)
            out = jnp.concatenate([tok0[None], toks], axis=0)  # (n,B)
            return cache, out.T, key

        return jax.jit(run)

    def _chunk_fn(self, n_steps: int):
        """Fixed-size decode chunk: ``n_steps`` masked decode steps in ONE
        ``lax.scan`` — the continuous-batching quantum.  Jitted once per
        chunk size, so admissions/retirements between chunks never
        recompile anything."""
        cfg, fam, temp = self.cfg, self.fam, self.temperature
        masked = cfg.family in ("transformer", "hymba")

        def run(params, cache, tok, key, active):
            def step(carry, _):
                cache, tok, key = carry
                if masked:
                    logits, cache = fam.decode_step(params, cache, tok,
                                                    cfg, active=active)
                else:
                    logits, cache = fam.decode_step(params, cache, tok, cfg)
                nxt, key = sample_token(logits, key, temp)
                return (cache, nxt, key), nxt

            (cache, _, key), toks = lax.scan(
                step, (cache, tok, key), length=n_steps)
            return cache, toks.T, key                     # (B, n_steps)

        return jax.jit(run)

    def decode_chunk(self, cache, tokens, n_steps: int, *, active=None):
        """Advance every slot by ``n_steps`` decode steps in one compiled
        dispatch; returns (cache, (B, n_steps) int32 sampled tokens).

        ``tokens``: (B,) the last sampled token per row (admission seeds
        this from the prefill logits).  ``active``: (B,) bool — inactive
        (empty / already-finished) rows still run through the batched
        model but their ``lens`` metadata stays frozen and their sampled
        tokens are garbage the scheduler discards.

        Raises if the chunk would run the write frontier past ``max_len``
        — the frontier is concrete between dispatches, so the guard is
        free, and without it the traced in-chunk writes would be silently
        DROPPED (the no-clamp guarantee), corrupting the tokens.  Callers
        (the scheduler) compact the cache first instead.
        """
        from repro.core.tracing import is_tracer
        if "block_tables" in cache:
            lens = cache["lens"]
            if not is_tracer(lens):
                act = np.ones((np.asarray(lens).shape[0],), bool) \
                    if active is None else np.asarray(active, bool)
                if act.any():
                    hi = int(np.asarray(lens)[act].max())
                    if hi + int(n_steps) > self.max_len:
                        raise ValueError(
                            f"decode_chunk: paged row frontier {hi} + "
                            f"{int(n_steps)} steps exceeds engine "
                            f"max_len {self.max_len}; retire rows first")
        elif not is_tracer(cache["len"]) and \
                int(cache["len"]) + int(n_steps) > self.max_len:
            raise ValueError(
                f"decode_chunk: frontier {int(cache['len'])} + "
                f"{int(n_steps)} steps exceeds engine max_len "
                f"{self.max_len}; compact the cache (kvcache.compact) "
                "or retire rows first")
        tokens = jnp.asarray(tokens, jnp.int32)
        b = tokens.shape[0]
        active = jnp.ones((b,), bool) if active is None \
            else jnp.asarray(active, bool)
        fn = self._get_jit(self._decode_jit, ("chunk", int(n_steps)),
                           lambda: self._chunk_fn(int(n_steps)))
        cache, toks, self._key = self._dispatch(
            fn, self.params, cache, tokens, self._key, active)
        if "block_tables" in cache:
            cache = self.shard_cache(cache)
        return cache, toks

    def _check_fits(self, padded_len: int, max_new_tokens: int):
        need = padded_len + max_new_tokens - 1        # last token not cached
        if need > self.max_len:
            raise ValueError(
                f"prompt ({padded_len}) + {max_new_tokens} new tokens "
                f"needs {need} cache slots > engine max_len {self.max_len}")

    def generate(self, prompts, max_new_tokens: int, *, frames=None,
                 visual=None) -> GenerationResult:
        """Prefill + scan-decode ``max_new_tokens`` tokens in ONE compiled
        decode call.  Raises up front if the request cannot fit in the
        preallocated ``max_len`` — out-of-capacity writes never clamp."""
        tokens, _ = self.pack_prompts(prompts)
        self._check_fits(tokens.shape[1], max_new_tokens)
        cache, logits, lens = self.prefill(
            prompts, frames=frames, visual=visual,
            reserve_tokens=max_new_tokens - 1)
        fn = self._get_jit(self._decode_jit, max_new_tokens,
                           lambda: self._decode_fn(max_new_tokens))
        cache, toks, self._key = self._dispatch(
            fn, self.params, cache, logits, self._key)
        return GenerationResult(tokens=np.asarray(toks),
                                prompt_lens=np.asarray(lens),
                                prefill_logits=np.asarray(logits),
                                cache=cache)

    def generate_stepwise(self, prompts, max_new_tokens: int, *,
                          frames=None, visual=None) -> GenerationResult:
        """Reference path: same sampling, but one jitted decode_step per
        token (Python-loop dispatch).  Produces tokens identical to
        ``generate`` — kept for tests and dispatch-overhead benchmarks."""
        tokens, _ = self.pack_prompts(prompts)
        self._check_fits(tokens.shape[1], max_new_tokens)
        cache, logits, lens = self.prefill(
            prompts, frames=frames, visual=visual,
            reserve_tokens=max_new_tokens - 1)
        fam, cfg = self.fam, self.cfg
        step_fn = self._get_jit(
            self._decode_jit, "step",
            lambda: jax.jit(lambda p, c, t: fam.decode_step(p, c, t, cfg)))
        key = self._key
        tok, key = sample_token(logits, key, self.temperature)
        outs = [tok]
        for _ in range(max_new_tokens - 1):
            step_logits, cache = self._dispatch(
                step_fn, self.params, cache, tok)
            tok, key = sample_token(step_logits, key, self.temperature)
            outs.append(tok)
        self._key = key
        return GenerationResult(
            tokens=np.stack([np.asarray(t) for t in outs], axis=1),
            prompt_lens=np.asarray(lens),
            prefill_logits=np.asarray(logits), cache=cache)
