"""Continuous-batching request scheduler over the serving engine.

The engine (PR 3) decodes a *fixed* batch: every request prefills
together and decodes together, so one long generation pins the whole
batch and finished rows burn decode steps producing garbage.  Under
ragged traffic that is exactly the goodput collapse continuous batching
(Orca-style iteration-level scheduling) fixes: treat the preallocated
cache's batch dimension as a SLOT POOL, retire rows the moment they
finish, and prefill queued prompts into the freed rows between decode
chunks.

Mechanics
---------
* ``submit()`` enqueues a request (prompt + per-request ``max_new_tokens``
  / ``eos_id``); ``step()`` runs one scheduling round:

      retire finished slots  ->  admit queued prompts into free slots
      ->  ONE fixed-size decode chunk (a single compiled ``lax.scan``
      dispatch whose shapes never change, so the DECODE path never
      recompiles; admission prefill is jit-specialized per prompt
      length — pad/bucket prompt lengths client-side if cold-prefill
      latency spikes matter)

* admission prefills the prompt alone (batch 1 — byte-identical to what
  an isolated ``Engine.generate`` would compute), samples the first
  token from the prefill logits, then grafts the row into the pool with
  ``kvcache.adopt_row``; the pool keeps ONE shared padded write frontier
  (``cache['len']``) and per-row valid counts (``lens``), so each row's
  RoPE positions and attention masks stay content-relative — a row
  admitted at frontier 40 generates exactly the tokens it would have
  generated alone (see ``tests/test_scheduler.py``).
* retirement is ``kvcache.reset_slots`` (lens -> 0 + content wipe); the
  shared frontier is pulled back by ``kvcache.compact`` whenever the next
  chunk would not fit, so slot reuse never exhausts ``max_len``.
* inactive rows ride along in the batched decode with frozen ``lens``
  (``decode_step(active=...)``) and their sampled tokens are discarded.

Paged mode (``Engine(paged=True)``) swaps the dense pool for the
block-table layout and DELETES compaction from this loop entirely:
addressing is row-local, so admission packs the prompt's KV into
freshly allocated arena blocks (``kvcache.paged_adopt_row``) without
touching any other row, retirement frees the row's blocks back to the
host-side ``BlockPool``, and live rows lazily extend their tables
between chunks.  Each request's worst-case block demand is RESERVED at
admission, so extension can never find the pool empty; when a
reservation does not fit, admission defers (FIFO) until retirements
free blocks.  Peak cache memory is the blocks actually resident
(``Σ tokens`` rounded up) instead of ``slots x max_len``, and the
token streams are identical to the compaction scheduler's
(``tests/test_paged.py``).

Sampling: greedy decoding is deterministic and token-identical to
isolated generation.  With ``temperature > 0`` the scheduler is still
deterministic for a fixed seed, but the PRNG stream interleaves rows
differently than isolated calls would, so per-request identity only
holds for greedy.

Time is measured in *decode steps* (the simulation clock): wall-clock
per step is constant for a fixed pool, so step-latency and goodput
ratios transfer to hardware.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.compress import kvcache as kvc
from repro.models import get_family
from .engine import Engine, sample_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_step: int = 0          # simulation clock at submit()


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray             # (n,) int32, truncated at EOS/max_new
    arrival_step: int              # when the request was submitted
    admitted_step: int             # decode-step clock at admission
    finished_step: int             # decode-step clock when retired

    @property
    def latency_steps(self) -> int:
        return self.finished_step - self.arrival_step

    @property
    def queue_steps(self) -> int:
        return self.admitted_step - self.arrival_step


@dataclasses.dataclass
class _Slot:
    req: Request
    emitted: list
    admitted_step: int
    done: bool = False

    @property
    def lens(self) -> int:
        """Row's cache occupancy: prompt + generated-so-far minus the
        not-yet-cached last token (mirrors the device ``lens`` entry)."""
        return len(self.req.prompt) + len(self.emitted) - 1


class Scheduler:
    """Iteration-level (continuous) batching over an :class:`Engine`.

    ``n_slots`` is the pool width (the compiled batch size), ``chunk_size``
    the number of decode steps between scheduling decisions.  Larger
    chunks amortize host work; smaller chunks admit/retire sooner.
    """

    def __init__(self, engine: Engine, *, n_slots: int,
                 chunk_size: int = 8, eos_id: Optional[int] = None):
        if engine.cfg.family != "transformer":
            raise ValueError(
                "continuous batching needs per-row decode positions, "
                "which only the transformer family provides (got family="
                f"{engine.cfg.family!r}); hymba/rwkv/whisper decode at a "
                "shared absolute position")
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.engine = engine
        self.n_slots = int(n_slots)
        self.chunk_size = int(chunk_size)
        self.eos_id = eos_id
        self.paged = bool(getattr(engine, "paged", False))
        fam = get_family(engine.cfg)
        if self.paged:
            from repro.models import transformer as T
            self.block_size = engine.block_size
            self.table_width = engine.table_width
            self.n_blocks = engine.n_blocks or \
                self.n_slots * self.table_width
            self.pool = kvc.BlockPool(self.n_blocks)
            self.cache = T.init_paged_cache(
                engine.cfg, self.n_slots, engine.max_len,
                self.block_size, self.n_blocks)
            self._window = T._paged_window(engine.cfg)
            self._tables = np.full(
                (self.n_slots, self.table_width), self.n_blocks, np.int32)
            self._row_blocks: list = [[] for _ in range(self.n_slots)]
            self._worst = [0] * self.n_slots
            self._outstanding = 0      # reserved-but-unallocated blocks
            # high-water mark of allocated + reserved blocks: an arena
            # of this size replays the same trace with zero deferrals
            # (the benchmark's capacity-planning number)
            self.peak_committed = 0
            self._adopt_paged = jax.jit(
                kvc.paged_adopt_row,
                static_argnames=("window", "src_ring"))
            self._release = jax.jit(kvc.paged_release_rows)
        else:
            self.cache = fam.init_cache(engine.cfg, self.n_slots,
                                        engine.max_len)
        self._slots: list = [None] * self.n_slots
        self._queue: deque = deque()
        self._cur_tok = np.zeros((self.n_slots,), np.int32)
        self._frontier = 0             # host mirror of cache["len"]
        self._next_rid = 0
        self.steps_run = 0             # decode steps executed (sim clock)
        self.n_chunks = 0
        self.n_admitted = 0
        self.n_retired = 0
        # cache-surgery ops, jitted once (shapes are fixed by the pool)
        self._reset = jax.jit(kvc.reset_slots)
        self._compact = jax.jit(lambda c, t: kvc.compact(c, t))
        self._adopt = jax.jit(kvc.adopt_row)

    # ------------------------------------------------------------------
    # queue
    # ------------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, *,
               eos_id: Optional[int] = None) -> int:
        """Enqueue a request; returns its request id.

        Raises up front if the request could never fit: a row may need
        ``prompt + max_new - 1`` cache slots plus a full chunk of
        frontier headroom (a row can overshoot its stopping point by up
        to ``chunk_size - 1`` steps before retirement is detected).
        """
        prompt = [int(t) for t in prompt]
        max_new_tokens = int(max_new_tokens)
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}")
        need = len(prompt) + max_new_tokens - 1 + self.chunk_size
        if need > self.engine.max_len:
            raise ValueError(
                f"request needs up to {need} cache slots (prompt "
                f"{len(prompt)} + {max_new_tokens} new + chunk "
                f"{self.chunk_size} headroom) > engine max_len "
                f"{self.engine.max_len}")
        if self.paged:
            worst = self._worst_blocks(len(prompt), max_new_tokens)
            if worst > self.n_blocks:
                raise ValueError(
                    f"request needs up to {worst} cache blocks > block "
                    f"pool capacity {self.n_blocks} (block_size "
                    f"{self.block_size})")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid=rid, prompt=prompt,
                                   max_new_tokens=max_new_tokens,
                                   eos_id=self.eos_id if eos_id is None
                                   else eos_id,
                                   arrival_step=self.steps_run))
        return rid

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(
            s is not None for s in self._slots)

    @property
    def n_active(self) -> int:
        return sum(1 for s in self._slots if s is not None and not s.done)

    # ------------------------------------------------------------------
    # scheduling round
    # ------------------------------------------------------------------

    def _set_frontier(self, target: int):
        if target != self._frontier:
            self.cache = self._compact(self.cache, jnp.int32(target))
            self._frontier = int(target)

    # -- paged block accounting ----------------------------------------

    def _worst_blocks(self, prompt_len: int, max_new: int) -> int:
        """Upper bound on the blocks a request can ever hold at once —
        reserved at admission so lazy per-chunk extension NEVER finds
        the pool empty.  Same formula the engine allocates by: a row
        can overshoot its stopping point by up to a full chunk."""
        return self.engine._row_blocks_needed(
            prompt_len, max_new - 1 + self.chunk_size)

    def _admit_paged(self, req: Request, row: int):
        plen = len(req.prompt)
        worst = self._worst_blocks(plen, req.max_new_tokens)
        if self.pool.n_free - self._outstanding < worst:
            return False               # wait for retirements' blocks
        # batch-1 LINEAR prefill: the same jitted path (and therefore
        # the same KV values) an isolated Engine.generate would run;
        # the prompt is then packed into freshly allocated blocks —
        # admission never moves other rows (nothing to compact)
        row_cache, logits, _ = self.engine.prefill([req.prompt],
                                                   paged=False)
        now = self.table_width if self.engine.window_lane else \
            -(-plen // self.block_size)
        ids = self.pool.alloc(now)
        block_ids = np.full((self.table_width,), self.n_blocks, np.int32)
        block_ids[:now] = ids
        cap = min(self.engine.max_len, self._window) if self._window \
            else self.engine.max_len
        self.cache = self._adopt_paged(
            self.cache, row_cache, jnp.int32(row),
            jnp.asarray(block_ids), window=self._window,
            src_ring=plen > cap)
        self._tables[row] = block_ids
        self._row_blocks[row] = ids
        self._worst[row] = worst
        self._outstanding += worst - now
        self.peak_committed = max(
            self.peak_committed, self.pool.in_use + self._outstanding)
        tok0, self.engine._key = sample_token(
            logits, self.engine._key, self.engine.temperature)
        return int(np.asarray(tok0)[0])

    def _ensure_blocks(self):
        """Extend each live dense row's table to cover the next chunk's
        writes (window rows never grow: their ring recycles in place).
        The admission-time reservation guarantees the pool can serve
        this."""
        changed = False
        for i, slot in enumerate(self._slots):
            if slot is None or slot.done or self.engine.window_lane:
                continue
            need = -(-min(slot.lens + self.chunk_size,
                          self.engine.max_len) // self.block_size)
            have = len(self._row_blocks[i])
            if need > have:
                ids = self.pool.alloc(need - have)
                self._tables[i, have:need] = ids
                self._row_blocks[i].extend(ids)
                self._outstanding -= len(ids)
                changed = True
        if changed:
            self.cache = dict(self.cache,
                              block_tables=jnp.asarray(self._tables))

    def _admit(self):
        free = [i for i, s in enumerate(self._slots) if s is None]
        while self._queue and free:
            req = self._queue[0]
            row = free[0]
            if self.paged:
                tok0 = self._admit_paged(req, row)
                if tok0 is False:      # pool cannot cover the request yet
                    break              # FIFO: do not admit around it
            else:
                plen = len(req.prompt)
                # batch-1 prefill: the same jitted path (and therefore
                # the same KV bytes) an isolated Engine.generate would
                # run
                row_cache, logits, _ = self.engine.prefill([req.prompt])
                tok0, self.engine._key = sample_token(
                    logits, self.engine._key, self.engine.temperature)
                tok0 = int(np.asarray(tok0)[0])
                if plen > self._frontier:  # long prompt: raise frontier
                    self._set_frontier(plen)
                self.cache = self._adopt(self.cache, row_cache,
                                         jnp.int32(row))
            self._queue.popleft()
            free.pop(0)
            slot = _Slot(req=req, emitted=[tok0],
                         admitted_step=self.steps_run)
            # a request can finish on its very first (prefill) token
            if tok0 == req.eos_id or req.max_new_tokens == 1:
                slot.done = True
            self._slots[row] = slot
            self._cur_tok[row] = tok0
            self.n_admitted += 1

    def _retire(self):
        done_mask = np.zeros((self.n_slots,), bool)
        completions = []
        for i, slot in enumerate(self._slots):
            if slot is None or not slot.done:
                continue
            done_mask[i] = True
            req = slot.req
            completions.append(Completion(
                rid=req.rid, prompt_len=len(req.prompt),
                tokens=np.asarray(slot.emitted, np.int32),
                arrival_step=req.arrival_step,
                admitted_step=slot.admitted_step,
                finished_step=self.steps_run))
            self._slots[i] = None
            self.n_retired += 1
            if self.paged:
                self.pool.free(self._row_blocks[i])
                self._outstanding -= \
                    self._worst[i] - len(self._row_blocks[i])
                self._row_blocks[i] = []
                self._worst[i] = 0
                self._tables[i] = self.n_blocks          # sentinel
        if done_mask.any():
            if self.paged:
                # lens -> 0 + sentinel tables; freed arena blocks are
                # overwritten wholesale on reuse, nothing to wipe
                self.cache = self._release(self.cache,
                                           jnp.asarray(done_mask))
            else:
                self.cache = self._reset(self.cache,
                                         jnp.asarray(done_mask))
        return completions

    def step(self):
        """One scheduling round; returns the requests completed in it."""
        self._admit()
        active = np.array(
            [s is not None and not s.done for s in self._slots], bool)
        if not active.any():
            # admissions can complete instantly (EOS on the prefill
            # token); surface those without burning a decode chunk
            return self._retire()

        if self.paged:
            # no shared frontier: rows extend their own block tables
            self._ensure_blocks()
        elif self._frontier + self.chunk_size > self.engine.max_len:
            # reclaim headroom freed by retirements / short rows
            target = max(s.lens for s in self._slots
                         if s is not None and not s.done)
            self._set_frontier(target)

        self.cache, toks = self.engine.decode_chunk(
            self.cache, self._cur_tok, self.chunk_size,
            active=jnp.asarray(active))
        toks = np.asarray(toks)
        if not self.paged:
            self._frontier += self.chunk_size  # mirror of cache["len"]
        self.steps_run += self.chunk_size
        self.n_chunks += 1

        for i in np.nonzero(active)[0]:
            slot = self._slots[i]
            req = slot.req
            for t in toks[i]:
                slot.emitted.append(int(t))
                if int(t) == req.eos_id or \
                        len(slot.emitted) >= req.max_new_tokens:
                    slot.done = True
                    break
            self._cur_tok[i] = toks[i, -1]
        return self._retire()

    def run(self, max_rounds: Optional[int] = None):
        """Drain queue + slots; returns ``{rid: Completion}``."""
        out = {}
        rounds = 0
        while self.has_work:
            if max_rounds is not None and rounds >= max_rounds:
                raise RuntimeError(
                    f"scheduler did not drain in {max_rounds} rounds "
                    f"({len(self._queue)} queued, {self.n_active} active)")
            for c in self.step():
                out[c.rid] = c
            rounds += 1
        return out
