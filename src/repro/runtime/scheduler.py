"""Continuous-batching request scheduler over the serving engine.

The engine (PR 3) decodes a *fixed* batch: every request prefills
together and decodes together, so one long generation pins the whole
batch and finished rows burn decode steps producing garbage.  Under
ragged traffic that is exactly the goodput collapse continuous batching
(Orca-style iteration-level scheduling) fixes: treat the preallocated
cache's batch dimension as a SLOT POOL, retire rows the moment they
finish, and prefill queued prompts into the freed rows between decode
chunks.

Mechanics
---------
* ``submit()`` enqueues a request (prompt + per-request ``max_new_tokens``
  / ``eos_id``); ``step()`` runs one scheduling round:

      retire finished slots  ->  admit queued prompts into free slots
      ->  ONE fixed-size decode chunk (a single compiled ``lax.scan``
      dispatch whose shapes never change, so the DECODE path never
      recompiles; unchunked admission prefill is jit-specialized per
      prompt length — ``chunked_prefill=True`` deletes that
      specialization entirely by feeding prompts through the decode
      lane in fixed-size chunks)

* admission prefills the prompt alone (batch 1 — byte-identical to what
  an isolated ``Engine.generate`` would compute), samples the first
  token from the prefill logits, then grafts the row into the pool with
  ``kvcache.adopt_row``; the pool keeps ONE shared padded write frontier
  (``cache['len']``) and per-row valid counts (``lens``), so each row's
  RoPE positions and attention masks stay content-relative — a row
  admitted at frontier 40 generates exactly the tokens it would have
  generated alone (see ``tests/test_scheduler.py``).
* retirement is ``kvcache.reset_slots`` (lens -> 0 + content wipe); the
  shared frontier is pulled back by ``kvcache.compact`` whenever the next
  chunk would not fit, so slot reuse never exhausts ``max_len``.
* inactive rows ride along in the batched decode with frozen ``lens``
  (``decode_step(active=...)``) and their sampled tokens are discarded.

Paged mode (``Engine(paged=True)``) swaps the dense pool for the
block-table layout and DELETES compaction from this loop entirely:
addressing is row-local, so admission packs the prompt's KV into
freshly allocated arena blocks (``kvcache.paged_adopt_row``) without
touching any other row, retirement frees the row's blocks back to the
host-side ``BlockPool``, and live rows lazily extend their tables
between chunks.  Each request's worst-case block demand is RESERVED at
admission, so extension can never find the pool empty; when a
reservation does not fit, admission defers (FIFO) until retirements
free blocks.  Peak cache memory is the blocks actually resident
(``Σ tokens`` rounded up) instead of ``slots x max_len``, and the
token streams are identical to the compaction scheduler's
(``tests/test_paged.py``).

Chunked prefill (``chunked_prefill=True``, paged only) deletes the
whole-prompt prefill specialization path: admission only ALLOCATES a
row (block table + ``lens = 0``), and the prompt then flows through the
decode lane in fixed ``chunk_size``-token chunks — every scheduling
round issues ONE ``Engine.mixed_step`` dispatch that runs a prefill
chunk for every prefilling row (``T.prefill_chunk``, a no-op for rows
with nothing to prefill) followed by the usual masked decode quantum
for every decoding row.  The compiled shape depends only on
``(n_slots, chunk_size)`` — never on any prompt length — so the engine
compiles the serving loop ONCE and ``Engine.n_compiles`` stays flat no
matter how ragged the admitted prompt lengths are (the
recompile-per-prompt-length bug class, pinned in
``tests/test_scheduler.py``).  A row that completes its prompt mid-
round samples its first token from that chunk's last-valid-position
logits and starts decoding the following round.  Greedy token streams
are bitwise-identical to the unchunked scheduler's: ``prefill_chunk``
pads the KV length to fixed ``attn_chunk_kv`` blocks so the online-
softmax reduction groups identically for every split of the same
prompt (see ``models/layers.py``).

Policy layer: ``submit(..., deadline=...)`` attaches an absolute
sim-step deadline; admission is earliest-deadline-first (deadline-less
requests sort last, FIFO among equals — with no deadlines in the queue
the order is plain FIFO, keeping the PR 6 traces schedule-identical).
When the EDF head cannot be admitted for lack of blocks, the scheduler
PREEMPTS the active row with the LATEST deadline — only if strictly
later than the candidate's, so best-effort never preempts best-effort
and livelock is impossible — by releasing its blocks (refcount-safe:
owned blocks are freed, borrowed prefix blocks decref'd, the prefix
index keeps registered blocks resident) and requeueing the request
from scratch.  Greedy decode makes the restart token-identical to an
uninterrupted run (``tests/test_scheduler.py``); under ``sanitize``
the released blocks are poisoned and the leak gauge stays zero.

Prefix caching (``prefix_cache=True``, implies chunked prefill)
deduplicates shared prompt prefixes across requests: every
fully-written prompt block is content-addressed in a
:class:`kvcache.PrefixIndex` (rolling hash of its token ids, chained
so a hash identifies the whole prefix up to that block), and admission
first walks the index — matched leading blocks are BORROWED
(``BlockPool.share``) instead of recomputed, and the chunk cursor
starts AFTER them (``min(matched * block_size, plen - 1)``: matched
blocks skip their chunks entirely; at least the last prompt token
reruns because its logits seed the first sampled token).  Writes never
land in a shared block: admission copy-on-writes the matched blocks
the remaining chunks overlap, the per-round write tables sentinel
every still-borrowed entry, and a pre-round pass COWs window-lane ring
slots about to recycle a shared block.  The index holds one pool
reference per registered block so prefixes survive their owner's
retirement; index-only blocks (refcount 1) are evicted LRU-first when
admission needs physical capacity.  Worst-case reservation stays
sound: a sharer's debt is ``worst - owned`` minus the dense-lane
borrowed blocks append-only writes can never touch, and window rows
pre-reserve one COW per ring slot they may register (reserved at
admission, settled when the fully-written prompt registers).  Greedy
token streams are identical to the non-sharing paged path
(``tests/test_prefix.py``) when the KV storage dtype is the compute
dtype; with a posit KV codec the borrowed prefix is read back through
the codec (exactly what decode reads), so logits past the prefix can
differ in the last ulp from a from-scratch prefill's.

Sampling: greedy decoding is deterministic and token-identical to
isolated generation.  With ``temperature > 0`` the scheduler is still
deterministic for a fixed seed, but the PRNG stream interleaves rows
differently than isolated calls would, so per-request identity only
holds for greedy.

Time is measured in *decode steps* (the simulation clock): wall-clock
per step is constant for a fixed pool, so step-latency and goodput
ratios transfer to hardware.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.compress import kvcache as kvc
from repro.models import get_family
from .engine import Engine, sample_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_step: int = 0          # simulation clock at submit()
    deadline: Optional[int] = None  # absolute sim-step SLO (None = none)


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray             # (n,) int32, truncated at EOS/max_new
    arrival_step: int              # when the request was submitted
    admitted_step: int             # decode-step clock at admission
    finished_step: int             # decode-step clock when retired

    @property
    def latency_steps(self) -> int:
        return self.finished_step - self.arrival_step

    @property
    def queue_steps(self) -> int:
        return self.admitted_step - self.arrival_step


@dataclasses.dataclass
class _Slot:
    req: Request
    emitted: list
    admitted_step: int
    done: bool = False
    # chunked-prefill cursor: prompt positions already cached, or None
    # once the whole prompt is in (always None in unchunked mode)
    cursor: Optional[int] = None

    @property
    def lens(self) -> int:
        """Row's cache occupancy: the chunk cursor while prefilling,
        else prompt + generated-so-far minus the not-yet-cached last
        token (mirrors the device ``lens`` entry)."""
        if self.cursor is not None:
            return self.cursor
        return len(self.req.prompt) + len(self.emitted) - 1


class Scheduler:
    """Iteration-level (continuous) batching over an :class:`Engine`.

    ``n_slots`` is the pool width (the compiled batch size), ``chunk_size``
    the number of decode steps between scheduling decisions — and, in
    chunked mode, also the prefill chunk width.  Larger chunks amortize
    host work; smaller chunks admit/retire sooner.
    ``chunked_prefill=True`` (paged engines only) routes prompts through
    the decode lane in fixed-size chunks so ONE compiled dispatch shape
    serves every request; ``prefix_cache=True`` (implies chunked
    prefill) switches on content-addressed prefix sharing with
    copy-on-write block tables — see the module docstring for the full
    contract.
    """

    def __init__(self, engine: Engine, *, n_slots: int,
                 chunk_size: int = 8, eos_id: Optional[int] = None,
                 prefix_cache: bool = False,
                 chunked_prefill: bool = False):
        if engine.cfg.family != "transformer":
            raise ValueError(
                "continuous batching needs per-row decode positions, "
                "which only the transformer family provides (got family="
                f"{engine.cfg.family!r}); hymba/rwkv/whisper decode at a "
                "shared absolute position")
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.engine = engine
        self.n_slots = int(n_slots)
        self.chunk_size = int(chunk_size)
        self.eos_id = eos_id
        self.paged = bool(getattr(engine, "paged", False))
        self.prefix_cache = bool(prefix_cache)
        # prefix borrows are expressed as chunk-cursor skips, so sharing
        # rides on the chunked machinery
        self.chunked = bool(chunked_prefill) or self.prefix_cache
        # arena sanitizer: inherited from the engine so one flag arms
        # both halves (host-side BlockPool checks + device poisoning)
        self.sanitize = bool(getattr(engine, "sanitize", False))
        if self.prefix_cache and not self.paged:
            raise ValueError(
                "prefix_cache=True needs Engine(paged=True): sharing "
                "is expressed through block-table entries")
        if self.chunked and not self.paged:
            raise ValueError(
                "chunked_prefill=True needs Engine(paged=True): chunks "
                "write through per-row block tables")
        fam = get_family(engine.cfg)
        if self.paged:
            from repro.models import transformer as T
            self.block_size = engine.block_size
            self.table_width = engine.table_width
            self.n_blocks = engine.n_blocks or \
                self.n_slots * self.table_width
            self.pool = kvc.BlockPool(self.n_blocks,
                                      sanitize=self.sanitize)
            # tensor-parallel engines place the arena head-sharded over
            # 'model' here; one logical block id names one slice per
            # shard, so the host-side pool/table bookkeeping below is
            # identical with or without a mesh
            self.cache = engine.shard_cache(T.init_paged_cache(
                engine.cfg, self.n_slots, engine.max_len,
                self.block_size, self.n_blocks))
            self._window = T._paged_window(engine.cfg)
            self._tables = np.full(
                (self.n_slots, self.table_width), self.n_blocks, np.int32)
            self._row_blocks: list = [[] for _ in range(self.n_slots)]
            # borrowed table entries: slot index -> shared block id; the
            # row holds one pool reference per entry but must COW before
            # ever writing through it (empty unless prefix_cache)
            self._row_borrowed: list = [{} for _ in range(self.n_slots)]
            self._row_used = [0] * self.n_slots   # populated table slots
            self._worst = [0] * self.n_slots
            self._outstanding = 0      # reserved-but-unallocated blocks
            # high-water mark of PHYSICAL allocated + reserved blocks:
            # an arena of this size replays the same trace with zero
            # deferrals (the benchmark's capacity-planning number).
            # peak_logical is the same mark counting every reference —
            # what a non-sharing pool would have needed; the gap is the
            # prefix-dedup win.
            self.peak_committed = 0
            self.peak_logical = 0
            # window+prefix rows reserve their registration COW head at
            # admission; settled when the fully-written prompt registers
            self._head_reserved = [0] * self.n_slots
            if self.prefix_cache:
                self.index = kvc.PrefixIndex()
            self._adopt_paged = jax.jit(
                kvc.paged_adopt_row,
                static_argnames=("window", "src_ring"))
            self._release = jax.jit(kvc.paged_release_rows)
        else:
            self.cache = fam.init_cache(engine.cfg, self.n_slots,
                                        engine.max_len)
        # prefix-caching observability (stay zero without prefix_cache)
        self.prefill_tokens = 0        # tokens actually run through prefill
        self.prefix_hits = 0           # admissions that borrowed blocks
        self.prefix_matched_tokens = 0  # prompt tokens served from cache
        self.n_cow = 0                 # copy-on-write block duplications
        self.n_evicted = 0             # index blocks reclaimed under pressure
        # sanitizer leak gauge: allocated blocks unreachable from any
        # live row, borrowed reference, or the prefix index, recomputed
        # at every retirement (0 on a healthy trace; see leak_report)
        self.n_leaked = 0
        self._slots: list = [None] * self.n_slots
        self._queue: deque = deque()
        self._cur_tok = np.zeros((self.n_slots,), np.int32)
        self._frontier = 0             # host mirror of cache["len"]
        self._next_rid = 0
        self.steps_run = 0             # decode steps executed (sim clock)
        # real wall time per scheduling round (ms), measured around
        # step(): the first half of the wall-clock-SLO roadmap item.
        # Observability only — EDF/preemption still run on the
        # decode-step sim clock (serve.MS_PER_STEP)
        self._step_wall_ms: list = []
        self.n_chunks = 0
        self.n_admitted = 0
        self.n_retired = 0
        self.n_preempted = 0           # rows evicted for an earlier deadline
        # cache-surgery ops, jitted once (shapes are fixed by the pool)
        self._reset = jax.jit(kvc.reset_slots)
        self._compact = jax.jit(lambda c, t: kvc.compact(c, t))
        self._adopt = jax.jit(kvc.adopt_row)

    # ------------------------------------------------------------------
    # queue
    # ------------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int, *,
               eos_id: Optional[int] = None,
               deadline: Optional[int] = None) -> int:
        """Enqueue a request; returns its request id.

        ``deadline``: absolute sim-step (``steps_run`` clock) the
        request should finish by.  Deadlines drive EDF admission and
        preemption (see the module docstring); ``None`` marks the
        request best-effort — it sorts after every deadline and is the
        first preemption victim.

        Raises up front if the request could never fit: a row may need
        ``prompt + max_new - 1`` cache slots plus a full chunk of
        frontier headroom (a row can overshoot its stopping point by up
        to ``chunk_size - 1`` steps before retirement is detected).
        """
        prompt = [int(t) for t in prompt]
        max_new_tokens = int(max_new_tokens)
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}")
        need = len(prompt) + max_new_tokens - 1 + self.chunk_size
        if need > self.engine.max_len:
            raise ValueError(
                f"request needs up to {need} cache slots (prompt "
                f"{len(prompt)} + {max_new_tokens} new + chunk "
                f"{self.chunk_size} headroom) > engine max_len "
                f"{self.engine.max_len}")
        if self.paged:
            worst = self._worst_blocks(len(prompt), max_new_tokens)
            if self.prefix_cache and self.engine.window_lane and \
                    self._share_cap(len(prompt)):
                # registered ring blocks each pre-reserve one COW copy
                worst += len(prompt) // self.block_size
            if worst > self.n_blocks:
                raise ValueError(
                    f"request needs up to {worst} cache blocks > block "
                    f"pool capacity {self.n_blocks} (block_size "
                    f"{self.block_size})")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid=rid, prompt=prompt,
                                   max_new_tokens=max_new_tokens,
                                   eos_id=self.eos_id if eos_id is None
                                   else eos_id,
                                   arrival_step=self.steps_run,
                                   deadline=None if deadline is None
                                   else int(deadline)))
        return rid

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(
            s is not None for s in self._slots)

    @property
    def n_active(self) -> int:
        return sum(1 for s in self._slots if s is not None and not s.done)

    @property
    def stats(self) -> dict:
        """Counters for one serving run — notably ``n_compiles``, the
        engine's distinct-lowered-program count: flat after warmup in
        chunked mode, growing with every new prompt length otherwise.
        ``step_wall_p50_ms``/``step_wall_p99_ms`` are REAL per-round
        wall times (0.0 before the first round); the sim clock
        (``steps_run``) stays the scheduling time base."""
        wall = np.asarray(self._step_wall_ms, np.float64)
        d = dict(
            n_admitted=self.n_admitted, n_retired=self.n_retired,
            n_preempted=self.n_preempted, n_chunks=self.n_chunks,
            steps_run=self.steps_run,
            step_wall_p50_ms=float(np.percentile(wall, 50))
            if wall.size else 0.0,
            step_wall_p99_ms=float(np.percentile(wall, 99))
            if wall.size else 0.0,
            prefill_tokens=self.prefill_tokens,
            prefix_hits=self.prefix_hits,
            prefix_matched_tokens=self.prefix_matched_tokens,
            n_cow=self.n_cow, n_evicted=self.n_evicted,
            n_leaked=self.n_leaked,
            n_compiles=self.engine.n_compiles)
        if self.paged:
            d.update(peak_committed=self.peak_committed,
                     peak_logical=self.peak_logical)
        return d

    # ------------------------------------------------------------------
    # scheduling round
    # ------------------------------------------------------------------

    def _set_frontier(self, target: int):
        if target != self._frontier:
            self.cache = self._compact(self.cache, jnp.int32(target))
            self._frontier = int(target)

    # -- paged block accounting ----------------------------------------

    def _worst_blocks(self, prompt_len: int, max_new: int) -> int:
        """Upper bound on the blocks a request can ever hold at once —
        reserved at admission so lazy per-chunk extension NEVER finds
        the pool empty.  Same formula the engine allocates by: a row
        can overshoot its stopping point by up to a full chunk."""
        return self.engine._row_blocks_needed(
            prompt_len, max_new - 1 + self.chunk_size)

    def _share_cap(self, plen: int) -> bool:
        """Is a prompt of ``plen`` tokens eligible for prefix sharing /
        registration?  The window lane shares only prompts that fit the
        window: then every logical block is resident at its identity
        ring slot, so donor and sharer agree on the slot -> position
        mapping (ring recycling of shared blocks is handled by the
        pre-chunk COW pass)."""
        if not self._window:
            return True
        return plen <= min(self.engine.max_len, self._window)

    def _evictable_count(self, exclude=()) -> int:
        """Index blocks whose ONLY reference is the index's — physical
        capacity admission may reclaim (minus ``exclude``: the blocks
        the current match is about to pin)."""
        ex = {int(i) for i in exclude}
        return sum(1 for b in self.index.blocks_lru()
                   if b not in ex and self.pool.refcount(b) == 1)

    def _take_blocks(self, n: int) -> list:
        """``pool.alloc(n)``, evicting least-recently-matched index-only
        blocks first if the free list is short.  Callers have already
        checked ``n_free + evictable`` covers their reservation."""
        if n > self.pool.n_free:
            evicted = []
            for bid in self.index.blocks_lru():
                if self.pool.n_free >= n:
                    break
                if self.pool.refcount(bid) == 1:
                    self.index.pop_block(bid)
                    evicted += self.pool.free([bid])
                    self.n_evicted += 1
            if self.sanitize and evicted:
                # evicted blocks may linger on the free list past the
                # alloc below — poison them so any stale index/table
                # path that still names them reads garbage, loudly
                self.cache = self.engine.poison_blocks(self.cache, evicted)
        return self.pool.alloc(n)

    def _match_prefix(self, prompt) -> list:
        """Longest chain of resident index blocks covering the prompt's
        leading full blocks; returns their physical ids."""
        ids = []
        for h in kvc.prefix_block_hashes(prompt, self.block_size):
            bid = self.index.get(h)
            if bid is None:
                break
            ids.append(int(bid))
        return ids

    def _register_row(self, prompt, row: int):
        """Content-address this row's fully-written prompt blocks.  Each
        newly registered block gets one extra pool reference HELD BY THE
        INDEX, so the prefix outlives the row; window rows additionally
        grow their reservation by one block per registration, because
        ring recycling will COW each shared slot at most once (chunked
        admission pre-reserved ``_head_reserved`` blocks for this —
        settle against it)."""
        plen = len(prompt)
        reserved, self._head_reserved[row] = self._head_reserved[row], 0
        n_reg = 0
        if self._share_cap(plen):
            for i, h in enumerate(kvc.prefix_block_hashes(
                    prompt, self.block_size)):
                if self.index.get(h) is not None:
                    continue           # first writer wins
                bid = int(self._tables[row, i])
                if bid == self.n_blocks:
                    continue
                self.index.put(h, bid)
                self.pool.share([bid])
                n_reg += 1
        if self.engine.window_lane and (n_reg or reserved):
            self._worst[row] += n_reg - reserved
            self._outstanding += n_reg - reserved

    def _row_debt(self, row: int) -> int:
        """Blocks still reserved (but not yet drawn) for a live row:
        worst-case minus owned.  Dense-lane borrowed entries are
        excluded — append-only decode can never write into a block that
        lies wholly before the suffix, so they need no COW reserve;
        window-lane borrowed entries keep theirs (ring recycling COWs
        each at most once)."""
        debt = self._worst[row] - len(self._row_blocks[row])
        if not self.engine.window_lane:
            debt -= len(self._row_borrowed[row])
        return debt

    def _note_peaks(self):
        # physical commitment excludes index-only blocks: those are
        # droppable cache (_take_blocks evicts them on demand), so an
        # arena of peak_committed still replays the trace deferral-free
        evictable = self._evictable_count() if self.prefix_cache else 0
        self.peak_committed = max(
            self.peak_committed,
            self.pool.in_use - evictable + self._outstanding)
        self.peak_logical = max(
            self.peak_logical,
            self.pool.logical_in_use + self._outstanding)

    def _admit_paged(self, req: Request, row: int):
        """Unchunked paged admission: whole-prompt linear prefill +
        block adoption (prefix caching never reaches here — it implies
        chunked mode)."""
        plen = len(req.prompt)
        worst = self._worst_blocks(plen, req.max_new_tokens)
        # reservation check: extension draws must never find the pool
        # empty
        if self.pool.n_free - self._outstanding < worst:
            return False               # wait for retirements' blocks
        # batch-1 LINEAR prefill: the same jitted path (and therefore
        # the same KV values) an isolated Engine.generate would run;
        # the prompt is then packed into freshly allocated blocks —
        # admission never moves other rows (nothing to compact)
        row_cache, logits, _ = self.engine.prefill([req.prompt],
                                                   paged=False)
        now = self.table_width if self.engine.window_lane else \
            -(-plen // self.block_size)
        ids = self.pool.alloc(now)
        block_ids = np.full((self.table_width,), self.n_blocks, np.int32)
        block_ids[:now] = ids
        cap = min(self.engine.max_len, self._window) if self._window \
            else self.engine.max_len
        self.cache = self.engine.shard_cache(self._adopt_paged(
            self.cache, row_cache, jnp.int32(row),
            jnp.asarray(block_ids), window=self._window,
            src_ring=plen > cap))
        self._tables[row] = block_ids
        self._row_blocks[row] = ids
        self._row_borrowed[row] = {}
        self._row_used[row] = now
        self._worst[row] = worst
        self._outstanding += worst - now
        self.prefill_tokens += plen
        self._note_peaks()
        tok0, self.engine._key = sample_token(
            logits, self.engine._key, self.engine.temperature)
        return int(np.asarray(tok0)[0])

    def _admit_chunked(self, req: Request, row: int):
        """Chunked admission: ALLOCATE only — block table, ``lens``
        cursor, prefix borrows.  No model dispatch happens here; the
        prompt flows through ``mixed_step`` chunks in subsequent
        rounds.  Returns the starting chunk cursor (0, or past the
        borrowed prefix on a hit), or ``None`` if the pool cannot cover
        the reservation yet."""
        plen = len(req.prompt)
        bs = self.block_size
        worst = self._worst_blocks(plen, req.max_new_tokens)
        matched, suffix_start = [], 0
        if self.prefix_cache and self._share_cap(plen):
            matched = self._match_prefix(req.prompt)
            # matched blocks skip their chunks entirely; at least the
            # last prompt token reruns — its logits seed tok0
            suffix_start = min(len(matched) * bs, plen - 1)
        # reservation check: COW/extension draws must never find the
        # pool empty.  Under prefix caching, index-only blocks count as
        # available — _take_blocks evicts them on demand; window rows
        # additionally pre-reserve one COW per block they may register
        # (settled at registration time, when the prompt is written).
        head = plen // bs if (
            self.prefix_cache and self.engine.window_lane and
            self._share_cap(plen)) else 0
        avail = self.pool.n_free + (
            self._evictable_count(exclude=matched)
            if self.prefix_cache else 0)
        if avail - self._outstanding < worst + head:
            return None                # wait for retirements' blocks
        used = self.table_width if self.engine.window_lane else \
            -(-plen // bs)
        block_ids = np.full((self.table_width,), self.n_blocks, np.int32)
        borrowed = {}
        if matched and suffix_start > 0:
            cow_from = suffix_start // bs  # first slot chunks write
            n_borrow = min(len(matched), cow_from)
            # pin the whole match BEFORE any eviction can reclaim it
            self.pool.share(matched)
            cow_slots = list(range(cow_from, len(matched)))
            fresh = self._take_blocks(
                used - len(matched) + len(cow_slots))
            block_ids[:len(matched)] = matched
            for s, nid in zip(cow_slots, fresh[:len(cow_slots)]):
                block_ids[s] = nid
            block_ids[len(matched):used] = fresh[len(cow_slots):]
            if cow_slots:
                # duplicate the pattern leaves block-for-block, then
                # drop our reference to the shared originals (the index
                # keeps them resident for future matches)
                self.cache = self.engine.copy_blocks(
                    self.cache, [matched[s] for s in cow_slots],
                    fresh[:len(cow_slots)])
                self.pool.release([matched[s] for s in cow_slots])
                self.n_cow += len(cow_slots)
            borrowed = {s: int(matched[s]) for s in range(n_borrow)}
            self.prefix_hits += 1
            self.prefix_matched_tokens += suffix_start
        else:
            suffix_start = 0
            fresh = self._take_blocks(used) if self.prefix_cache \
                else self.pool.alloc(used)
            block_ids[:used] = fresh
        self._tables[row] = block_ids
        self.cache = dict(
            self.cache,
            block_tables=jnp.asarray(self._tables),
            lens=jnp.asarray(self.cache["lens"],
                             jnp.int32).at[row].set(suffix_start))
        self._row_blocks[row] = list(fresh)
        self._row_borrowed[row] = borrowed
        self._row_used[row] = used
        self._worst[row] = worst
        self._head_reserved[row] = head
        self._worst[row] += head       # reserve the registration COWs
        self._outstanding += self._row_debt(row)
        self._note_peaks()
        return suffix_start

    def _write_span(self, slot):
        """Inclusive logical block range ``[lo, hi]`` the next round's
        writes may touch for this slot: the imminent prefill chunk while
        the cursor is live, else the decode quantum.  ``None`` if the
        round writes nothing for it."""
        bs = self.block_size
        if slot.cursor is not None:    # prefilling: this round's chunk
            n = min(self.chunk_size, len(slot.req.prompt) - slot.cursor)
            if n <= 0:
                return None
            return slot.cursor // bs, (slot.cursor + n - 1) // bs
        lo = slot.lens
        return lo // bs, (lo + self.chunk_size - 1) // bs

    def _cow_window_rows(self) -> bool:
        """Pre-chunk COW pass (window lane + prefix_cache only): the
        ring recycles blocks in place, so the next round's writes may
        land in blocks that are shared (borrowed from a donor, or this
        row's own registered prefix).  Duplicate each such block and
        swap the table entry first; the admission-time reservation
        covers every copy."""
        src, dst = [], []
        w = self.table_width
        for i, slot in enumerate(self._slots):
            if slot is None or slot.done:
                continue
            span = self._write_span(slot)
            if span is None:
                continue
            lo, hi = span
            for q in range(lo, hi + 1):
                s = q % w
                bid = int(self._tables[i, s])
                if bid == self.n_blocks or self.pool.refcount(bid) <= 1:
                    continue
                nid, = self._take_blocks(1)
                src.append(bid)
                dst.append(nid)
                self._tables[i, s] = nid
                self._row_blocks[i].append(nid)
                self._outstanding -= 1
                if self._row_borrowed[i].pop(s, None) is None:
                    # own registered block: index keeps the original
                    self._row_blocks[i].remove(bid)
                self.pool.release([bid])
                self.n_cow += 1
        if src:
            self.cache = self.engine.copy_blocks(self.cache, src, dst)
            return True
        return False

    def _ensure_blocks(self):
        """Extend each live dense row's table to cover the next chunk's
        writes (window rows never grow: their ring recycles in place —
        but under prefix caching recycled SHARED blocks are first
        duplicated by the COW pass).  The admission-time reservation
        guarantees the pool can serve this."""
        changed = False
        for i, slot in enumerate(self._slots):
            if slot is None or slot.done or self.engine.window_lane:
                continue
            if slot.cursor is not None:
                continue               # prompt blocks were allocated whole
            need = -(-min(slot.lens + self.chunk_size,
                          self.engine.max_len) // self.block_size)
            have = self._row_used[i]
            if need > have:
                ids = self._take_blocks(need - have) if self.prefix_cache \
                    else self.pool.alloc(need - have)
                self._tables[i, have:need] = ids
                self._row_blocks[i].extend(ids)
                self._row_used[i] = need
                self._outstanding -= len(ids)
                changed = True
        if self.prefix_cache and self.engine.window_lane:
            changed |= self._cow_window_rows()
        if changed:
            self.cache = dict(self.cache,
                              block_tables=jnp.asarray(self._tables))

    def _sanitize_check_chunk(self):
        """Pre-chunk sanitizer gate (``sanitize=True`` only): every
        resident table entry of a live row must still be allocated
        (``check_read`` — stale entries are use-after-free gathers) and
        every block the imminent round writes through must be
        exclusively owned (``check_write`` — refcount > 1 here means a
        COW pass was skipped and the write would corrupt every other
        owner's KV).  The write span mirrors ``_cow_window_rows``
        (``_write_span``: the prefill chunk while the cursor is live,
        the decode quantum after), mapped through the ring on the
        window lane."""
        w = self.table_width
        for i, slot in enumerate(self._slots):
            if slot is None or slot.done:
                continue
            row = self._tables[i]
            self.pool.check_read(
                int(b) for b in row if int(b) != self.n_blocks)
            span = self._write_span(slot)
            if span is None:
                continue
            lo, hi = span
            if self.engine.window_lane:
                slots_touched = {q % w for q in range(lo, hi + 1)}
            else:
                slots_touched = range(lo, min(hi, w - 1) + 1)
            self.pool.check_write(
                int(row[s]) for s in slots_touched
                if int(row[s]) != self.n_blocks)

    # -- policy: EDF ordering + preemption ------------------------------

    def _order_queue(self):
        """Earliest-deadline-first admission order (stable, so FIFO
        among equal deadlines and deadline-less requests).  With no
        deadlines in the queue this is a no-op — the PR 6 traces stay
        schedule-identical."""
        if any(r.deadline is not None for r in self._queue):
            self._queue = deque(sorted(
                self._queue,
                key=lambda r: float("inf") if r.deadline is None
                else r.deadline))

    def _preempt_row(self, i: int):
        """Evict a live row to free its blocks: drop every reference
        (owned blocks free, borrowed prefix blocks decref — the index
        keeps registered blocks resident), sentinel the table, zero the
        device ``lens``, and requeue the request from scratch.  Greedy
        decode makes the restart token-identical to an uninterrupted
        run; already-emitted tokens are discarded."""
        slot = self._slots[i]
        self._slots[i] = None
        self.n_preempted += 1
        self._outstanding -= self._row_debt(i)
        reclaimed = self.pool.free(self._row_blocks[i])
        if self._row_borrowed[i]:
            reclaimed += self.pool.release(
                list(self._row_borrowed[i].values()))
        self._row_blocks[i] = []
        self._row_borrowed[i] = {}
        self._row_used[i] = 0
        self._worst[i] = 0
        self._head_reserved[i] = 0
        self._tables[i] = self.n_blocks          # sentinel
        mask = np.zeros((self.n_slots,), bool)
        mask[i] = True
        self.cache = self.engine.shard_cache(
            self._release(self.cache, jnp.asarray(mask)))
        if self.sanitize:
            if reclaimed:
                self.cache = self.engine.poison_blocks(
                    self.cache, reclaimed)
            self.n_leaked = len(self.leak_report())
        self._queue.append(slot.req)   # original arrival_step preserved

    def _try_preempt(self, req: Request) -> bool:
        """Preemption-by-block-release: when the EDF head cannot be
        admitted, evict the active row with the LATEST deadline — only
        if strictly later than the candidate's (best-effort rows count
        as latest), so best-effort never preempts best-effort and the
        loop cannot livelock."""
        if not self.paged:
            return False
        cd = float("inf") if req.deadline is None else req.deadline
        victim, vd_max = None, cd
        for i, s in enumerate(self._slots):
            if s is None or s.done:
                continue
            vd = float("inf") if s.req.deadline is None \
                else s.req.deadline
            if vd > vd_max:
                victim, vd_max = i, vd
        if victim is None:
            return False
        self._preempt_row(victim)
        return True

    def _admit(self):
        self._order_queue()
        free = [i for i, s in enumerate(self._slots) if s is None]
        while self._queue and free:
            req = self._queue[0]
            row = free[0]
            if self.chunked:
                cursor = self._admit_chunked(req, row)
                if cursor is None:     # pool cannot cover the request yet
                    if self._try_preempt(req):
                        self._order_queue()
                        free = [i for i, s in enumerate(self._slots)
                                if s is None]
                        continue
                    break              # EDF: do not admit around the head
                self._queue.popleft()
                free.remove(row)
                self._slots[row] = _Slot(
                    req=req, emitted=[],
                    admitted_step=self.steps_run, cursor=cursor)
                self.n_admitted += 1
                continue
            if self.paged:
                tok0 = self._admit_paged(req, row)
                if tok0 is False:      # pool cannot cover the request yet
                    if self._try_preempt(req):
                        self._order_queue()
                        free = [i for i, s in enumerate(self._slots)
                                if s is None]
                        continue
                    break              # EDF: do not admit around the head
            else:
                plen = len(req.prompt)
                # batch-1 prefill: the same jitted path (and therefore
                # the same KV bytes) an isolated Engine.generate would
                # run
                row_cache, logits, _ = self.engine.prefill([req.prompt])
                tok0, self.engine._key = sample_token(
                    logits, self.engine._key, self.engine.temperature)
                tok0 = int(np.asarray(tok0)[0])
                if plen > self._frontier:  # long prompt: raise frontier
                    self._set_frontier(plen)
                self.cache = self._adopt(self.cache, row_cache,
                                         jnp.int32(row))
            self._queue.popleft()
            free.remove(row)
            slot = _Slot(req=req, emitted=[tok0],
                         admitted_step=self.steps_run)
            # a request can finish on its very first (prefill) token
            if tok0 == req.eos_id or req.max_new_tokens == 1:
                slot.done = True
            self._slots[row] = slot
            self._cur_tok[row] = tok0
            self.n_admitted += 1

    def leak_report(self) -> set:
        """Sanitizer leak accounting: allocated block ids unreachable
        from any live row's owned blocks, any borrowed table entry, or
        the prefix index.  A non-empty set means references were dropped
        without ``free``/``release`` — those blocks can never be
        reclaimed.  Valid to call any time; ``_retire`` refreshes the
        ``n_leaked`` gauge from it."""
        if not self.paged:
            return set()
        held: set = set()
        for ids in self._row_blocks:
            held.update(int(b) for b in ids)
        for borrowed in self._row_borrowed:
            held.update(int(b) for b in borrowed.values())
        if self.prefix_cache:
            held.update(int(b) for b in self.index.blocks_lru())
        return set(self.pool.allocated_ids()) - held

    def _retire(self):
        done_mask = np.zeros((self.n_slots,), bool)
        completions = []
        reclaimed: list = []
        for i, slot in enumerate(self._slots):
            if slot is None or not slot.done:
                continue
            done_mask[i] = True
            req = slot.req
            completions.append(Completion(
                rid=req.rid, prompt_len=len(req.prompt),
                tokens=np.asarray(slot.emitted, np.int32),
                arrival_step=req.arrival_step,
                admitted_step=slot.admitted_step,
                finished_step=self.steps_run))
            self._slots[i] = None
            self.n_retired += 1
            if self.paged:
                # drop the row's references: owned blocks physically
                # reclaim unless the prefix index still holds them;
                # borrowed blocks just decref back to their other owners
                self._outstanding -= self._row_debt(i)
                reclaimed += self.pool.free(self._row_blocks[i])
                if self._row_borrowed[i]:
                    reclaimed += self.pool.release(
                        list(self._row_borrowed[i].values()))
                self._row_blocks[i] = []
                self._row_borrowed[i] = {}
                self._row_used[i] = 0
                self._worst[i] = 0
                self._head_reserved[i] = 0
                self._tables[i] = self.n_blocks          # sentinel
        if done_mask.any():
            if self.paged:
                # lens -> 0 + sentinel tables; freed arena blocks are
                # overwritten wholesale on reuse, nothing to wipe —
                # except under the sanitizer, which poisons them so a
                # stale table entry detonates instead of silently
                # serving freed KV
                self.cache = self.engine.shard_cache(
                    self._release(self.cache, jnp.asarray(done_mask)))
                if self.sanitize:
                    if reclaimed:
                        self.cache = self.engine.poison_blocks(
                            self.cache, reclaimed)
                    self.n_leaked = len(self.leak_report())
            else:
                self.cache = self._reset(self.cache,
                                         jnp.asarray(done_mask))
        return completions

    def _step_chunked(self):
        """One chunked scheduling round: admit (allocation only) ->
        extend/COW/sanitize for the combined prefill+decode write spans
        -> ONE ``mixed_step`` dispatch (a prefill chunk for every
        prefilling row, the decode quantum for every decoding row —
        compiled once, for every prompt length) -> advance cursors,
        sample first tokens for rows that completed their prompt, emit
        decode tokens -> retire."""
        self._admit()
        decode_active = np.array(
            [s is not None and not s.done and s.cursor is None
             for s in self._slots], bool)
        nv = np.zeros((self.n_slots,), np.int32)
        chunk = np.full((self.n_slots, self.chunk_size),
                        self.engine.pad_id, np.int32)
        for i, s in enumerate(self._slots):
            if s is None or s.done or s.cursor is None:
                continue
            n = min(self.chunk_size, len(s.req.prompt) - s.cursor)
            nv[i] = n
            chunk[i, :n] = s.req.prompt[s.cursor:s.cursor + n]
        if not decode_active.any() and not nv.any():
            # admissions can complete instantly only via retirement of
            # already-done slots; surface those without a dispatch
            return self._retire()
        self._ensure_blocks()
        if self.sanitize:
            self._sanitize_check_chunk()
        # per-round write tables: every still-borrowed entry hidden
        # behind the sentinel so shared blocks never take a write (not
        # even a byte-identical write-back from pack_range)
        wt = self._tables.copy()
        for i, borrowed in enumerate(self._row_borrowed):
            for s in borrowed:
                wt[i, s] = self.n_blocks
        self.cache, chunk_logits, toks = self.engine.mixed_step(
            self.cache, chunk, nv, self._cur_tok, self.chunk_size,
            decode_active=decode_active, write_tables=wt)
        toks = np.asarray(toks)
        chunk_logits = np.asarray(chunk_logits)
        self.steps_run += self.chunk_size
        self.n_chunks += 1

        for i, s in enumerate(self._slots):
            if s is None or s.done:
                continue
            req = s.req
            if decode_active[i]:
                for t in toks[i]:
                    s.emitted.append(int(t))
                    if int(t) == req.eos_id or \
                            len(s.emitted) >= req.max_new_tokens:
                        s.done = True
                        break
                self._cur_tok[i] = toks[i, -1]
            elif nv[i]:
                s.cursor += int(nv[i])
                self.prefill_tokens += int(nv[i])
                if s.cursor >= len(req.prompt):
                    # prompt complete: first token comes from the
                    # chunk's last-valid-position logits, exactly where
                    # a whole-prompt prefill would have sampled it
                    s.cursor = None
                    if self.prefix_cache:
                        self._register_row(req.prompt, i)
                        self._note_peaks()
                    tok0, self.engine._key = sample_token(
                        jnp.asarray(chunk_logits[i:i + 1]),
                        self.engine._key, self.engine.temperature)
                    tok0 = int(np.asarray(tok0)[0])
                    s.emitted.append(tok0)
                    self._cur_tok[i] = tok0
                    if tok0 == req.eos_id or req.max_new_tokens == 1:
                        s.done = True
        return self._retire()

    def step(self):
        """One scheduling round; returns the requests completed in it.

        Order: admit queued prompts into free slots (EDF over any
        deadlines, FIFO otherwise; a paged admission defers until
        ``n_free + evictable - outstanding`` covers its worst-case
        block demand, preempting a strictly-later-deadline row if that
        unblocks the head) -> extend live dense rows' tables / COW
        window-lane ring slots about to recycle a shared block -> ONE
        fixed-size decode chunk (single compiled dispatch, shapes never
        change; in chunked mode the dispatch also carries every
        prefilling row's prompt chunk) -> retire finished rows (decref
        their blocks; prefix-registered blocks stay resident under the
        index's reference).  Invariants pinned by tests: greedy token
        streams identical to isolated generation and to the
        non-sharing paged path; writes reach a block only while its
        refcount is 1; reservation never lets extension or COW find
        the pool empty.

        Every round is wall-timed (``time.perf_counter``); ``stats``
        surfaces the p50/p99 in milliseconds next to the sim clock."""
        t0 = time.perf_counter()
        try:
            if self.chunked:
                return self._step_chunked()
            return self._step_unchunked()
        finally:
            self._step_wall_ms.append((time.perf_counter() - t0) * 1e3)

    def _step_unchunked(self):
        self._admit()
        active = np.array(
            [s is not None and not s.done for s in self._slots], bool)
        if not active.any():
            # admissions can complete instantly (EOS on the prefill
            # token); surface those without burning a decode chunk
            return self._retire()

        if self.paged:
            # no shared frontier: rows extend their own block tables
            self._ensure_blocks()
            if self.sanitize:
                self._sanitize_check_chunk()
        elif self._frontier + self.chunk_size > self.engine.max_len:
            # reclaim headroom freed by retirements / short rows
            target = max(s.lens for s in self._slots
                         if s is not None and not s.done)
            self._set_frontier(target)

        self.cache, toks = self.engine.decode_chunk(
            self.cache, self._cur_tok, self.chunk_size,
            active=jnp.asarray(active))
        toks = np.asarray(toks)
        if not self.paged:
            self._frontier += self.chunk_size  # mirror of cache["len"]
        self.steps_run += self.chunk_size
        self.n_chunks += 1

        for i in np.nonzero(active)[0]:
            slot = self._slots[i]
            req = slot.req
            for t in toks[i]:
                slot.emitted.append(int(t))
                if int(t) == req.eos_id or \
                        len(slot.emitted) >= req.max_new_tokens:
                    slot.done = True
                    break
            self._cur_tok[i] = toks[i, -1]
        return self._retire()

    def run(self, max_rounds: Optional[int] = None):
        """Drain queue + slots; returns ``{rid: Completion}``."""
        out = {}
        rounds = 0
        while self.has_work:
            if max_rounds is not None and rounds >= max_rounds:
                raise RuntimeError(
                    f"scheduler did not drain in {max_rounds} rounds "
                    f"({len(self._queue)} queued, {self.n_active} active)")
            for c in self.step():
                out[c.rid] = c
            rounds += 1
        return out
