"""GSPMD sharding rules: param-path regex -> PartitionSpec.

TP over 'model' (heads / ffn / vocab / experts), DP over ('pod','data')
on the batch, optional SP (sequence over 'model') via activation
constraints in the models.  Uneven dims (14 heads at TP=16, 40 experts at
EP=16) rely on GSPMD padding — flagged in the roofline notes.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def set_mesh(mesh: Mesh):
    """Context manager activating ``mesh``, across jax versions.

    jax >= 0.5 has ``jax.set_mesh``; on 0.4.x the Mesh object itself is
    the context manager.
    """
    setter = getattr(jax, "set_mesh", None)
    return setter(mesh) if setter is not None else mesh

# first match wins; paths look like "layers/attn/wq/w" or "tok_embed"
_TRANSFORMER_RULES = [
    (r"tok_embed$", P("model", None)),
    (r"pos_embed$", P(None, None)),
    (r"meta_tokens$", P(None, None)),
    (r"lm_head/w$", P(None, "model")),
    # attention projections (leading layer-stack axis)
    (r"layers.*/(wq|wk|wv)/w$", P(None, None, "model")),
    (r"layers.*/wo/w$", P(None, "model", None)),
    # MLA
    (r"layers.*/wdq/w$", P(None, None, "model")),
    (r"layers.*/wuq/w$", P(None, "model", None)),
    (r"layers.*/wdkv/w$", P(None, None, None)),
    (r"layers.*/(wuk|wuv)/w$", P(None, None, "model")),
    # dense mlp
    (r"layers.*/mlp/(wi|wg)/w$", P(None, None, "model")),
    (r"layers.*/mlp/wo/w$", P(None, "model", None)),
    # moe (EP over 'model')
    (r"layers.*/moe/router/w$", P(None, None, None)),
    (r"layers.*/moe/(wi|wg)$", P(None, "model", None, None)),
    (r"layers.*/moe/wo$", P(None, "model", None, None)),
    # rwkv
    (r"layers.*/(wr|wk|wv|wg|cm_wk|cm_wr)/w$", P(None, None, "model")),
    (r"layers.*/(cm_wv)/w$", P(None, "model", None)),
    (r"layers.*/tm_w1$", P(None, None, None)),
    (r"layers.*/tm_w2$", P(None, None, None, None)),
    (r"layers.*/wl_a$", P(None, None, None)),
    (r"layers.*/wl_b$", P(None, None, None)),
    # hymba ssm
    (r"layers.*/in_proj/w$", P(None, None, "model")),
    # whisper enc/dec stacks
    (r"(enc|dec)_layers.*/(wq|wk|wv)/w$", P(None, None, "model")),
    (r"(enc|dec)_layers.*/wo/w$", P(None, "model", None)),
    (r"(enc|dec)_layers.*/mlp/wi/w$", P(None, None, "model")),
    (r"(enc|dec)_layers.*/mlp/wo/w$", P(None, "model", None)),
    # projection biases: qkv/mlp-in biases shard with their matmul's
    # output features; wo biases add AFTER the TP all-reduce, replicated
    (r"(wq|wk|wv|wg|wi)/b$", P(None, "model")),
    (r"(wo|cm_wv)/b$", P(None, None)),
    # norms (scale/bias) are elementwise over the replicated residual
    (r"(ln[0-9]?|ln_x|ln_out|norm)/(scale|bias)$", P(None, None)),
    # rwkv mixing vectors + per-head decay/bonus, hymba ssm scalars:
    # tiny per-channel state, replicated
    (r"layers.*/(cm_maa_k|cm_maa_r|maa_x|w0|dt_bias|A_log|D)$",
     P(None, None)),
    (r"layers.*/maa_wkvrg$", P(None, None, None)),
    (r"layers.*/u$", P(None, None, None)),
]


def match_for_path(path_str: str):
    """First rule matching ``path_str`` as ``(pattern, spec)``, or
    ``None`` when no rule covers the path — the silent-replication
    fallthrough ``tests/test_sharding_rules.py`` pins against."""
    for pat, spec in _TRANSFORMER_RULES:
        if re.search(pat, path_str):
            return pat, spec
    return None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for_path(path_str: str, ndim: int) -> P:
    hit = match_for_path(path_str)
    if hit is not None:
        _, spec = hit
        if len(spec) == ndim:
            return spec
        # rank mismatch (e.g. an unstacked top-level norm): replicate
        return P(*([None] * ndim))
    return P(*([None] * ndim))


def _axis_size(entry, mesh: Mesh) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def filter_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop (replicate) any spec axis whose mesh size does not divide the
    dim — explicit in_shardings require exact divisibility."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for entry, size in zip(dims, shape):
        if entry is not None and (
                entry not in mesh.axis_names
                and not isinstance(entry, (tuple, list))):
            entry = None                      # axis absent from this mesh
        if entry is not None and size % _axis_size(entry, mesh) != 0:
            entry = None
        out.append(entry)
    return P(*out)


def _add_fsdp_axis(spec: P, shape, n_data: int) -> P:
    """ZeRO/FSDP: additionally shard params (and thus opt state) over
    'data' on the first unsharded dim divisible by the data axis size.
    GSPMD inserts the per-layer all-gathers automatically."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for i, (d, s) in enumerate(zip(dims, shape)):
        if d is None and s % n_data == 0 and s >= n_data:
            dims[i] = "data"
            return P(*dims)
    return spec


def param_specs(params_shape, mesh: Optional[Mesh] = None, *,
                fsdp: bool = False, n_data: int = 1) -> dict:
    """Tree of PartitionSpecs matching a params(-shaped) tree."""
    def one(path, leaf):
        spec = spec_for_path(_path_str(path), len(leaf.shape))
        if mesh is not None:
            spec = filter_spec(spec, leaf.shape, mesh)
        if fsdp and n_data > 1:
            spec = _add_fsdp_axis(spec, leaf.shape, n_data)
        return spec
    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape, mesh: Mesh, *, fsdp: bool = False):
    n_data = mesh.shape.get("data", 1)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params_shape, mesh, fsdp=fsdp, n_data=n_data))


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_axes(global_batch: int, mesh: Mesh):
    """Shard batch over ('pod','data') when divisible, else replicate."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if global_batch % max(n, 1) == 0 and n > 1:
        return tuple(axes)
    return None


def batch_specs(batch_shape, mesh: Mesh, cfg: ModelConfig,
                seq_shard: bool = False):
    """Specs for a data batch tree {'tokens': (B,S), ...}."""
    def one(path, leaf):
        b_axes = batch_axes(leaf.shape[0], mesh)
        rest = [None] * (len(leaf.shape) - 1)
        return P(b_axes, *rest)
    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_specs(cache_shape, mesh: Mesh, cfg: ModelConfig,
                *, seq_axis_shard: bool = False):
    """Specs for KV/state cache trees.

    The *sequence* axis of KV caches shards over 'model' (context
    parallelism for decode): it is always divisible, it parallelizes the
    bandwidth-bound cache reads across TP chips, and it works for MQA
    (kv=1) where head-sharding cannot.  GSPMD inserts the softmax
    reductions across shards.  With ``seq_axis_shard`` (long-context
    batch=1 cells) the T axis additionally takes 'data'.

    Layouts (leading layer-stack axis L):
      dense KV     (L, B, T, G, hd)  -> (None, batch, T_axes, None, None)
      MLA latents  (L, B, T, r)      -> (None, batch, T_axes, None)
      rwkv state   (L, B, H, N, V)   -> (None, batch, 'model', None, None)
      hymba ssm    (L, B, H, P, N)   -> (None, batch, 'model', None, None)
    """
    t_axes = ("model", "data") if (seq_axis_shard and "data" in
                                   mesh.axis_names) else "model"

    def one(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if name == "len" or nd == 0:
            return P()
        b_axes = batch_axes(leaf.shape[1], mesh) if nd > 1 else None
        if name in ("k", "v", "k_swa", "v_swa", "k_glb", "v_glb",
                    "ck", "cv"):
            spec = P(None, b_axes, t_axes, None, None)
        elif name in ("c_kv", "k_rope"):
            spec = P(None, b_axes, t_axes, None)
        elif name in ("wkv", "ssm"):
            spec = P(None, b_axes, "model", None, None)
        elif name in ("tm_x", "cm_x"):
            spec = P(None, b_axes, None)
        else:
            spec = P(*([None] * nd))
        return filter_spec(spec, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def paged_cache_specs(cache_shape, mesh: Mesh, cfg: ModelConfig):
    """Specs for the PAGED pool cache (block arena + tables).

    The arena reuses the dense cache's leaf names but NOT its axis
    semantics — axis 1 is the block id and axis 2 the in-block slot, so
    ``cache_specs``'s sequence-over-'model' rule would shard the
    16-wide block_size axis.  Blocks are head-partitioned instead:

      dense arena  (L, nb, bs, G, hd) -> (None, None, None, 'model', None)
      MLA latents  (L, nb, bs, r)     -> replicated (no head axis)
      metadata     block_tables/lens/max_len -> replicated (host-mirrored)

    One logical block id therefore names one slice per shard — the
    host-side ``BlockPool`` free list stays shard-agnostic, and
    refcount/COW/sanitizer semantics carry over unchanged.
    ``filter_spec`` drops the 'model' axis when it does not divide the
    KV head count (explicit placement needs exact divisibility).
    """
    def one(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if name in ("k", "v") and nd == 5:
            spec = P(None, None, None, "model", None)
        else:
            spec = P(*([None] * nd))
        return filter_spec(spec, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def paged_cache_shardings(cache_shape, mesh: Mesh, cfg: ModelConfig):
    return to_shardings(paged_cache_specs(cache_shape, mesh, cfg), mesh)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree)
