"""Fault tolerance & straggler mitigation for the training supervisor.

* ``StragglerWatchdog`` — EWMA step-time monitor; flags steps whose
  duration exceeds ``threshold`` x the moving average.  On a real cluster
  the flag triggers hot-spare swap / re-slicing; here it feeds metrics
  and the supervisor log (and is unit-tested with synthetic timings).
* ``TrainSupervisor`` — crash-safe outer loop: checkpoint every
  ``save_every`` steps, auto-resume from the latest complete checkpoint,
  bounded restarts.  Failure injection hooks make this testable on CPU.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.checkpoint.checkpointer import Checkpointer


@dataclasses.dataclass
class StragglerWatchdog:
    alpha: float = 0.2            # EWMA weight
    threshold: float = 2.5        # x mean -> straggler
    warmup: int = 3
    _mean: float = 0.0
    _count: int = 0
    stragglers: int = 0

    def observe(self, dt: float) -> bool:
        self._count += 1
        if self._count <= self.warmup:
            self._mean = dt if self._mean == 0 else \
                (self._mean + dt) / 2
            return False
        is_straggler = dt > self.threshold * self._mean
        if is_straggler:
            self.stragglers += 1
        else:
            self._mean = (1 - self.alpha) * self._mean + self.alpha * dt
        return is_straggler


class TrainSupervisor:
    def __init__(self, checkpointer: Checkpointer, *,
                 save_every: int = 50, max_restarts: int = 3,
                 watchdog: Optional[StragglerWatchdog] = None):
        self.ckpt = checkpointer
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.watchdog = watchdog or StragglerWatchdog()
        self.restarts = 0
        self.events = []

    def run(self, *, state, step_fn: Callable, total_steps: int,
            fail_hook: Optional[Callable] = None):
        """Run ``step_fn(state, step) -> state`` with checkpoint/restart.

        ``state`` must be a pytree; ``fail_hook(step)`` may raise to
        simulate node failure (tests).
        Returns (final state, steps executed including replays).
        """
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, start = self.ckpt.restore(latest, state)
            self.events.append(("resume", start))
        executed = 0
        step = start
        while step < total_steps:
            try:
                t0 = time.monotonic()
                if fail_hook is not None:
                    fail_hook(step)
                state = step_fn(state, step)
                executed += 1
                if self.watchdog.observe(time.monotonic() - t0):
                    self.events.append(("straggler", step))
                step += 1
                if step % self.save_every == 0 or step == total_steps:
                    self.ckpt.save(step, state)
            except Exception as e:                      # noqa: BLE001
                self.restarts += 1
                self.events.append(("failure", step, repr(e)))
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}") from e
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state, step = self.ckpt.restore(latest, state)
                else:
                    step = 0
                self.events.append(("resume", step))
        self.ckpt.wait()
        return state, executed
