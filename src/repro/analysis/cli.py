"""positcheck CLI.

Usage::

    PYTHONPATH=src python -m repro.analysis [paths ...]

Defaults to scanning ``src/``.  Exits non-zero on any non-waived
finding (regardless of severity) — this is the contract the CI lint
lane relies on.  ``--list-rules`` documents the rule set; ``--show-waived``
prints suppressed findings for auditability.
"""
from __future__ import annotations

import argparse
import sys

from .core import run_paths
from .rules import ALL_RULES


def list_rules() -> str:
    lines = ["positcheck rules:"]
    for r in ALL_RULES:
        lines.append(f"  {r.id} [{r.severity:7s}] {r.title}")
        lines.append(f"      fix: {r.hint}")
    lines.append(
        "\nwaive a finding with '# positcheck: disable=<ID>[,<ID>...]' "
        "(or disable=all) on the flagged line, plus a comment saying why "
        "the invariant holds there."
    )
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="positcheck: static analyzer for PVU serving-stack invariants",
    )
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to scan (default: src)")
    parser.add_argument("--list-rules", action="store_true",
                        help="describe the rule set and exit")
    parser.add_argument("--show-waived", action="store_true",
                        help="also print findings suppressed by waivers")
    parser.add_argument("--no-hints", action="store_true",
                        help="omit fix hints from the report")
    args = parser.parse_args(argv)

    if args.list_rules:
        print(list_rules())
        return 0

    active, waived, errors = run_paths(args.paths, ALL_RULES)

    for err in errors:
        print(f"positcheck: ERROR {err}", file=sys.stderr)
    for f in active:
        print(f.format(show_hint=not args.no_hints))
    if args.show_waived:
        for f in waived:
            print(f"[waived] {f.format(show_hint=False)}")

    n_err = sum(1 for f in active if f.severity == "error")
    n_warn = len(active) - n_err
    print(
        f"positcheck: {len(active)} finding(s) "
        f"({n_err} error, {n_warn} warning, {len(waived)} waived)"
    )
    return 1 if (active or errors) else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
