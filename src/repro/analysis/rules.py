"""The positcheck rules (PVU001–PVU007).

Each rule is a bug class this repo actually shipped (or nearly did);
see the module docstring of :mod:`repro.analysis` and the "Invariants &
enforcement" section of ``docs/ARCHITECTURE.md`` for the history.

Rules are syntactic and deliberately conservative: they match the
idioms used in this codebase, not every conceivable spelling.  A miss
is acceptable; a false positive on idiomatic repo code is not — anything
that must stay gets a per-line ``# positcheck: disable=PVUxxx`` waiver
with a comment explaining why the invariant holds there.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .core import ModuleFile, Rule

# ---------------------------------------------------------------------------
# shared walkers


def _calls_with_fstack(tree: ast.Module) -> Iterator[tuple[ast.Call, tuple[str, ...]]]:
    """Yield every Call with the names of its enclosing function defs."""

    def walk(node: ast.AST, stack: tuple[str, ...]):
        for child in ast.iter_child_nodes(node):
            child_stack = stack
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                child_stack = stack + (child.name,)
            if isinstance(child, ast.Call):
                yield child, stack
            yield from walk(child, child_stack)

    yield from walk(tree, ())


def _contains_cacheish_name(node: ast.AST) -> bool:
    """Does this expression mention a cache-derived variable?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and "cache" in sub.id.lower():
            return True
        if isinstance(sub, ast.Attribute) and "cache" in sub.attr.lower():
            return True
    return False


def _in_dirs(mod: ModuleFile, *dirs: str) -> bool:
    parts = mod.path.parts
    return any(d in parts for d in dirs)


def _is_file(mod: ModuleFile, suffix: str) -> bool:
    return mod.path.as_posix().endswith(suffix)


# ---------------------------------------------------------------------------
# PVU001 — raw dynamic_update_slice* cache writes (the clamp bug class)


class RawCacheWrite(Rule):
    id = "PVU001"
    severity = "error"
    title = "raw lax.dynamic_update_slice* outside the guarded helpers"
    hint = (
        "route the write through layers.guarded_cache_update (linear/ring "
        "caches) or layers.paged_cache_update (block tables; sentinel "
        "entries DROP) — lax.dynamic_update_slice* CLAMPS out-of-range "
        "starts and silently overwrites the last slot (the PR 3 decode "
        "bug). If clamping is provably impossible, waive with "
        "'# positcheck: disable=PVU001' plus a comment proving the bound."
    )

    DUS = {"dynamic_update_slice", "dynamic_update_slice_in_dim"}
    # the one approved wrapper: its body is the single sanctioned call site
    ALLOWED_FUNCS = {"guarded_cache_update"}

    def check(self, mod: ModuleFile):
        for call, fstack in _calls_with_fstack(mod.tree):
            leaf = self.call_name(call).rsplit(".", 1)[-1]
            if leaf in self.DUS and not (set(fstack) & self.ALLOWED_FUNCS):
                yield call, (
                    f"raw lax.{leaf} (clamps out-of-range start indices) "
                    "outside guarded_cache_update/paged_cache_update"
                )


# ---------------------------------------------------------------------------
# PVU002 — dequant→f32→requant round-trips outside kernels/ and compress/


class RequantRoundTrip(Rule):
    id = "PVU002"
    severity = "warning"
    title = "dequantize→f32→requantize round-trip outside approved internals"
    hint = (
        "the fused posit-domain kernels (kernels.ops.vadd/vsub/vmul/vdiv, "
        "pgemm) exist to replace decode→f32-op→re-encode round-trips "
        "(~11x at 64k elements); compute in the posit domain or move the "
        "round-trip into kernels/ or compress/ internals"
    )

    QUANT = {"f32_to_posit", "quantize", "quantize_cache"}
    DEQUANT = {"posit_to_f32", "dequantize", "dequantize_cache"}
    ALLOWED_DIRS = ("kernels", "compress")

    def check(self, mod: ModuleFile):
        if _in_dirs(mod, *self.ALLOWED_DIRS):
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if self.call_name(node).rsplit(".", 1)[-1] not in self.QUANT:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                for sub in ast.walk(arg):
                    if (isinstance(sub, ast.Call)
                            and self.call_name(sub).rsplit(".", 1)[-1] in self.DEQUANT):
                        yield node, (
                            "requantizing a freshly dequantized value "
                            "(dequant→f32→requant round-trip)"
                        )
                        break
                else:
                    continue
                break


# ---------------------------------------------------------------------------
# PVU003 — dtype sniffing on cache leaves instead of the leaf schema


class CacheDtypeSniff(Rule):
    id = "PVU003"
    severity = "error"
    title = "dtype sniffing on cache leaves instead of the leaf schema"
    hint = (
        "classify cache leaves by NAME via kvcache.CONTENT_LEAVES / "
        "META_LEAVES (the explicit schema PR 5 introduced) — dtype "
        "sniffing broke when int32 metadata leaves (lens, block_tables) "
        "joined the cache pytree"
    )

    # the schema implementation itself may inspect dtypes
    ALLOWED_FILE = "compress/kvcache.py"

    def check(self, mod: ModuleFile):
        if _is_file(mod, self.ALLOWED_FILE):
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                if self.call_name(node).rsplit(".", 1)[-1] != "issubdtype":
                    continue
                if node.args and _contains_cacheish_name(node.args[0]):
                    yield node, (
                        "issubdtype() on a cache-derived leaf — dtype "
                        "sniffing instead of the CONTENT_LEAVES/META_LEAVES "
                        "schema"
                    )
            elif isinstance(node, ast.Compare):
                if not all(isinstance(op, (ast.Eq, ast.NotEq)) for op in node.ops):
                    continue
                for side in [node.left] + node.comparators:
                    if (isinstance(side, ast.Attribute) and side.attr == "dtype"
                            and _contains_cacheish_name(side)):
                        yield node, (
                            "comparing .dtype of a cache-derived leaf — "
                            "dtype sniffing instead of the "
                            "CONTENT_LEAVES/META_LEAVES schema"
                        )
                        break


# ---------------------------------------------------------------------------
# PVU004 — python control flow on traced values in jit/scan contexts


class TracedBranch(Rule):
    id = "PVU004"
    severity = "error"
    title = "python if/assert on a traced value inside a jit/scan function"
    hint = (
        "python branches evaluate ONCE at trace time against abstract "
        "values (TracerBoolConversionError at best, silently-baked-in "
        "branch at worst); use lax.cond/lax.select/jnp.where for traced "
        "conditions, or hoist static config out of the traced function"
    )

    TRACING_WRAPPERS = {"jit"}
    # (call leaf name, indices of function-valued args)
    BODY_POSITIONS = {
        "scan": (0,),
        "while_loop": (0, 1),
        "fori_loop": (2,),
        "cond": (1, 2),
        "switch": (1, 2, 3, 4),
        "map": (0,),
    }
    STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
    STATIC_CALLS = {"len", "isinstance", "hasattr", "getattr", "type", "callable"}

    def _decorated_jit(self, fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
        for dec in fn.decorator_list:
            name = self.dotted_name(dec)
            if name.rsplit(".", 1)[-1] in self.TRACING_WRAPPERS:
                return True
            if isinstance(dec, ast.Call):
                cname = self.call_name(dec)
                if cname.rsplit(".", 1)[-1] in self.TRACING_WRAPPERS:
                    return True
                if cname.rsplit(".", 1)[-1] == "partial" and dec.args:
                    first = self.dotted_name(dec.args[0])
                    if first.rsplit(".", 1)[-1] in self.TRACING_WRAPPERS:
                        return True
        return False

    def _traced_names(self, tree: ast.Module) -> set[str]:
        """Names of local functions that get traced: jit(f) wrappings and
        lax.scan/while_loop/cond/... body arguments."""
        traced: set[str] = set()
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = self.call_name(node)
            leaf = name.rsplit(".", 1)[-1]
            if leaf in self.TRACING_WRAPPERS:
                for arg in node.args[:1]:
                    if isinstance(arg, ast.Name):
                        traced.add(arg.id)
            elif leaf in self.BODY_POSITIONS and ("lax" in name or leaf == "scan"):
                for i in self.BODY_POSITIONS[leaf]:
                    if i < len(node.args) and isinstance(node.args[i], ast.Name):
                        traced.add(node.args[i].id)
        return traced

    def _param_names(self, fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
        a = fn.args
        names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return {n for n in names if n not in ("self", "cls", "cfg", "config")}

    def _unsafe_param_use(self, test: ast.expr, params: set[str]) -> bool:
        """True if ``test`` uses a (likely traced) parameter in a way that
        forces concretization — i.e. not via static .shape/.ndim/.dtype
        attributes, len()/isinstance()-style host calls, or is/in ops."""
        parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(test):
            for child in ast.iter_child_nodes(node):
                parents[child] = node
        for node in ast.walk(test):
            if not (isinstance(node, ast.Name) and node.id in params):
                continue
            cur, safe = node, False
            while cur in parents:
                parent = parents[cur]
                if isinstance(parent, ast.Attribute) and parent.attr in self.STATIC_ATTRS:
                    safe = True
                    break
                if isinstance(parent, ast.Call) and cur in parent.args:
                    if self.call_name(parent).rsplit(".", 1)[-1] in self.STATIC_CALLS:
                        safe = True
                        break
                if isinstance(parent, ast.Compare):
                    ops = parent.ops
                    if all(isinstance(o, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                           for o in ops):
                        safe = True
                        break
                cur = parent
            if not safe:
                return True
        return False

    def check(self, mod: ModuleFile):
        traced_names = self._traced_names(mod.tree)
        for fn, _stack in self.functions_with_stack(mod.tree):
            if not (self._decorated_jit(fn) or fn.name in traced_names):
                continue
            params = self._param_names(fn)
            if not params:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.If):
                    test, kind = node.test, "if"
                elif isinstance(node, ast.Assert):
                    test, kind = node.test, "assert"
                else:
                    continue
                if self._unsafe_param_use(test, params):
                    yield node, (
                        f"python '{kind}' on a traced argument of "
                        f"'{fn.name}' (jit/scan-traced) — the branch is "
                        "evaluated once at trace time"
                    )


# ---------------------------------------------------------------------------
# PVU005 — reaching into BlockPool private allocator state


class PoolPrivateAccess(Rule):
    id = "PVU005"
    severity = "error"
    title = "BlockPool private state accessed outside the allocator"
    hint = (
        "go through the refcount API — alloc()/share()/release() (free is "
        "the decref alias) — never the private free list or refcount "
        "table; direct mutation desynchronizes refcounts from the "
        "PrefixIndex and corrupts copy-on-write (shared blocks get "
        "reused while still referenced)"
    )

    PRIVATE_ATTRS = {"_free", "_ref", "_freed"}
    ALLOWED_FILE = "compress/kvcache.py"

    def check(self, mod: ModuleFile):
        if _is_file(mod, self.ALLOWED_FILE):
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and node.attr in self.PRIVATE_ATTRS:
                yield node, (
                    f"direct access to BlockPool private state '.{node.attr}' "
                    "bypasses the refcount API (share/release)"
                )


# ---------------------------------------------------------------------------
# PVU006 — jit specialization on prompt-length-like static args


class PromptLenSpecialization(Rule):
    id = "PVU006"
    severity = "error"
    title = "jit static args specialize on a prompt-length-like value"
    hint = (
        "a jit whose static args carry a prompt/prefix/sequence length "
        "compiles one program PER LENGTH — the recompile-per-prompt "
        "stall chunked prefill (Engine.mixed_step, one compiled shape "
        "for every request) deleted; feed lengths in as traced arrays "
        "(per-row lens/n_valid) or route the dispatch through "
        "runtime/engine.py, the one place allowed to manage jit caches"
    )

    ALLOWED_FILE = "runtime/engine.py"
    JIT_NAMES = {"jit"}
    # length-like: 'plen' itself, or a *_len name scoped to prompt-ish
    # data.  Capacity statics (max_len, block/window sizes) stay legal.
    SCOPES = ("prompt", "prefix", "seq", "suffix", "token")

    def _length_like(self, name) -> bool:
        n = str(name).lower()
        if n in ("plen", "seqlen"):
            return True
        return "len" in n and any(s in n for s in self.SCOPES)

    def _is_jit_call(self, node: ast.Call) -> bool:
        leaf = self.call_name(node).rsplit(".", 1)[-1]
        if leaf in self.JIT_NAMES:
            return True
        if leaf == "partial" and node.args:
            first = self.dotted_name(node.args[0])
            return first.rsplit(".", 1)[-1] in self.JIT_NAMES
        return False

    def check(self, mod: ModuleFile):
        if _is_file(mod, self.ALLOWED_FILE):
            return
        fndefs = {
            f.name: f for f in ast.walk(mod.tree)
            if isinstance(f, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call) or not self._is_jit_call(node):
                continue
            for kw in node.keywords:
                if kw.arg == "static_argnames":
                    for sub in ast.walk(kw.value):
                        if (isinstance(sub, ast.Constant)
                                and isinstance(sub.value, str)
                                and self._length_like(sub.value)):
                            yield node, (
                                "jit static_argnames includes prompt-"
                                f"length-like {sub.value!r} — one "
                                "compiled program per prompt length, "
                                "outside the engine's jit caches"
                            )
                elif kw.arg == "static_argnums":
                    # resolve indices against a locally defined wrapped
                    # function, when one is named in the call
                    target = None
                    for a in node.args:
                        nm = self.dotted_name(a).rsplit(".", 1)[-1]
                        if nm in fndefs:
                            target = fndefs[nm]
                    if target is None:
                        continue
                    ta = target.args
                    params = [p.arg for p in ta.posonlyargs + ta.args]
                    for sub in ast.walk(kw.value):
                        if (isinstance(sub, ast.Constant)
                                and isinstance(sub.value, int)
                                and 0 <= sub.value < len(params)
                                and self._length_like(params[sub.value])):
                            yield node, (
                                "jit static_argnums position "
                                f"{sub.value} is prompt-length-like "
                                f"parameter {params[sub.value]!r} — one "
                                "compiled program per prompt length, "
                                "outside the engine's jit caches"
                            )


# ---------------------------------------------------------------------------
# PVU007 — cache/arena placement without sharding machinery


class UnshardedCachePlacement(Rule):
    id = "PVU007"
    severity = "error"
    title = "cache/arena leaf placed or created without sharding machinery"
    hint = (
        "a bare jax.device_put (or a fresh zeros/full arena) in runtime/ "
        "or models/ implicitly REPLICATES the KV cache on every device, "
        "silently forfeiting the per-shard footprint the head-sharded "
        "arena exists for; place cache trees through Engine.shard_cache / "
        "sharding.paged_cache_shardings (NamedSharding) or pin views with "
        "lax.with_sharding_constraint.  Sanctioned constructors (init_* "
        "functions, whose output the engine places) are exempt; anything "
        "else that must stay gets '# positcheck: disable=PVU007' plus a "
        "comment naming where placement happens."
    )

    SCOPED_DIRS = ("runtime", "models")
    CREATORS = {"zeros", "full", "empty", "zeros_like", "full_like"}
    SHARDY = ("shard", "constraint")

    @staticmethod
    def _cache_or_arena(node: ast.AST) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and (
                    "cache" in sub.id.lower() or "arena" in sub.id.lower()):
                return True
            if isinstance(sub, ast.Attribute) and (
                    "cache" in sub.attr.lower()
                    or "arena" in sub.attr.lower()):
                return True
        return False

    def _shardingish(self, node: ast.AST) -> bool:
        for sub in ast.walk(node):
            name = ""
            if isinstance(sub, (ast.Name, ast.Attribute)):
                name = self.dotted_name(sub) or getattr(sub, "attr", "")
            if any(s in name.lower() for s in self.SHARDY):
                return True
        return False

    def check(self, mod: ModuleFile):
        if not _in_dirs(mod, *self.SCOPED_DIRS):
            return
        # arm 1: device_put of a cache/arena tree with no sharding arg
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if self.call_name(node).rsplit(".", 1)[-1] != "device_put":
                continue
            if not node.args or not self._cache_or_arena(node.args[0]):
                continue
            rest = list(node.args[1:]) + [kw.value for kw in node.keywords]
            if not rest or not any(self._shardingish(a) for a in rest):
                yield node, (
                    "device_put of a cache/arena tree without a "
                    "NamedSharding — implicit replication on every device"
                )
        # arm 2: a fresh cache/arena materialized outside the sanctioned
        # init_* constructors, in a function that never touches sharding
        def walk(node: ast.AST, fn):
            for child in ast.iter_child_nodes(node):
                child_fn = fn
                if isinstance(child,
                              (ast.FunctionDef, ast.AsyncFunctionDef)):
                    child_fn = child
                if isinstance(child, (ast.Assign, ast.AnnAssign)):
                    yield child, fn
                yield from walk(child, child_fn)

        for assign, fn in walk(mod.tree, None):
            value = getattr(assign, "value", None)
            if not isinstance(value, ast.Call):
                continue
            if self.call_name(value).rsplit(".", 1)[-1] not in self.CREATORS:
                continue
            targets = (assign.targets if isinstance(assign, ast.Assign)
                       else [assign.target])
            if not any(self._cache_or_arena(t) for t in targets):
                continue
            if fn is not None and (fn.name.startswith("init")
                                   or self._shardingish(fn)):
                continue
            yield assign, (
                "fresh cache/arena materialized outside an init_* "
                "constructor with no sharding in sight — it lands "
                "replicated on every device"
            )


ALL_RULES: tuple[Rule, ...] = (
    RawCacheWrite(),
    RequantRoundTrip(),
    CacheDtypeSniff(),
    TracedBranch(),
    PoolPrivateAccess(),
    PromptLenSpecialization(),
    UnshardedCachePlacement(),
)


def rule_by_id(rule_id: str) -> Rule:
    for r in ALL_RULES:
        if r.id == rule_id:
            return r
    raise KeyError(rule_id)
