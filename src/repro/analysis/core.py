"""Rule framework for positcheck.

Deliberately small: a ``Rule`` owns an id/severity/fix-hint and a
``check(module)`` generator over a parsed ``ModuleFile``.  Waivers are
per-line comments (``# positcheck: disable=PVU001,PVU005`` or
``disable=all``) and suppress findings anchored on that line or on any
line of the flagged statement's span.  Everything here is stdlib-only so
the analyzer runs in environments without jax (the CI lint job).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Iterator, Sequence

WAIVER_RE = re.compile(r"#\s*positcheck:\s*disable=([A-Za-z0-9_,\s*]+)")

SEVERITIES = ("error", "warning")


@dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source line."""

    rule_id: str
    severity: str
    path: str  # display path (as given on the command line)
    line: int
    col: int
    message: str
    hint: str = ""

    def format(self, *, show_hint: bool = True) -> str:
        out = f"{self.path}:{self.line}:{self.col}: {self.rule_id} [{self.severity}] {self.message}"
        if show_hint and self.hint:
            out += f"\n    hint: {self.hint}"
        return out


@dataclass
class ModuleFile:
    """A parsed python module plus the waiver map extracted from it."""

    path: Path
    display: str
    source: str
    tree: ast.Module
    # line -> set of waived rule ids ("all" waives everything on the line)
    waivers: dict[int, set[str]] = field(default_factory=dict)

    @classmethod
    def parse(cls, path: Path, display: str | None = None) -> "ModuleFile":
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
        waivers: dict[int, set[str]] = {}
        for lineno, line in enumerate(source.splitlines(), start=1):
            m = WAIVER_RE.search(line)
            if m:
                ids = {tok.strip() for tok in m.group(1).split(",") if tok.strip()}
                waivers[lineno] = {("all" if i in ("all", "*") else i) for i in ids}
        return cls(path=path, display=display or str(path), source=source,
                   tree=tree, waivers=waivers)

    def is_waived(self, rule_id: str, node: ast.AST) -> bool:
        """A finding on ``node`` is waived if any line in the node's span
        (or the node's anchor line) carries a matching waiver comment."""
        lines = {getattr(node, "lineno", 0)}
        end = getattr(node, "end_lineno", None)
        if end is not None:
            lines.update(range(node.lineno, end + 1))
        for ln in lines:
            waived = self.waivers.get(ln)
            if waived and ("all" in waived or rule_id in waived):
                return True
        return False


class Rule:
    """Base class: subclasses set the class attributes and implement
    ``check`` yielding ``(node, message)`` pairs; the runner turns those
    into :class:`Finding`s and applies waivers."""

    id: str = "PVU000"
    severity: str = "error"
    title: str = ""
    hint: str = ""

    def check(self, mod: ModuleFile) -> Iterator[tuple[ast.AST, str]]:
        raise NotImplementedError
        yield  # pragma: no cover

    # -- shared AST helpers -------------------------------------------------

    @staticmethod
    def dotted_name(node: ast.AST) -> str:
        """``lax.dynamic_update_slice`` -> that string; '' if not a plain
        name/attribute chain."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
            return ".".join(reversed(parts))
        return ""

    @staticmethod
    def call_name(call: ast.Call) -> str:
        return Rule.dotted_name(call.func)

    @staticmethod
    def functions_with_stack(
        tree: ast.Module,
    ) -> Iterator[tuple[ast.FunctionDef | ast.AsyncFunctionDef, tuple[ast.AST, ...]]]:
        """Yield every function definition with its enclosing-scope stack
        (outermost first, excluding the function itself)."""

        def walk(node: ast.AST, stack: tuple[ast.AST, ...]):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield child, stack
                    yield from walk(child, stack + (child,))
                else:
                    yield from walk(child, stack + ((child,) if isinstance(
                        child, ast.ClassDef) else ()))

        yield from walk(tree, ())


def run_module(mod: ModuleFile, rules: Sequence[Rule]) -> tuple[list[Finding], list[Finding]]:
    """Run ``rules`` over one module.  Returns (active, waived) findings."""
    active: list[Finding] = []
    waived: list[Finding] = []
    for rule in rules:
        for node, message in rule.check(mod):
            f = Finding(
                rule_id=rule.id,
                severity=rule.severity,
                path=mod.display,
                line=getattr(node, "lineno", 0),
                col=getattr(node, "col_offset", 0) + 1,
                message=message,
                hint=rule.hint,
            )
            (waived if mod.is_waived(rule.id, node) else active).append(f)
    return active, waived


def iter_python_files(paths: Iterable[str | Path]) -> Iterator[tuple[Path, str]]:
    """Expand files/directories into ``(path, display)`` pairs, sorted."""
    for raw in paths:
        root = Path(raw)
        if root.is_dir():
            for p in sorted(root.rglob("*.py")):
                if "__pycache__" in p.parts:
                    continue
                yield p, str(p)
        elif root.suffix == ".py":
            yield root, str(root)


def run_paths(
    paths: Iterable[str | Path], rules: Sequence[Rule]
) -> tuple[list[Finding], list[Finding], list[str]]:
    """Analyze every python file under ``paths``.

    Returns (active findings, waived findings, unparseable-file errors).
    Findings are sorted by (path, line, rule id).
    """
    active: list[Finding] = []
    waived: list[Finding] = []
    errors: list[str] = []
    for path, display in iter_python_files(paths):
        try:
            mod = ModuleFile.parse(path, display)
        except (SyntaxError, UnicodeDecodeError) as e:
            errors.append(f"{display}: failed to parse: {e}")
            continue
        a, w = run_module(mod, rules)
        active.extend(a)
        waived.extend(w)
    key = lambda f: (f.path, f.line, f.rule_id)  # noqa: E731
    return sorted(active, key=key), sorted(waived, key=key), errors
