"""positcheck — repo-invariant static analyzer for the PVU serving stack.

Pure-stdlib (``ast`` + ``re``): the CI lint lane runs it without jax
installed, and ``python -m repro.analysis`` stays import-light because
``repro`` is a namespace package.

The rules encode bug classes we actually shipped:

- PVU001 — raw ``lax.dynamic_update_slice*`` cache writes (the PR 3
  decode clamp-overwrite class; writes must route through
  ``guarded_cache_update`` / ``paged_cache_update``).
- PVU002 — dequant→f32→requant round-trips outside ``kernels/`` and
  ``compress/`` (the fused PVU elementwise kernels exist to replace
  these).
- PVU003 — dtype/shape sniffing on cache leaves instead of the
  ``CONTENT_LEAVES``/``META_LEAVES`` schema (the pre-PR 5 tagging bug).
- PVU004 — Python ``if``/``assert`` on traced values inside
  jit-decorated or scan-body functions (trace-safety hazards).
- PVU005 — reaching into ``BlockPool`` private allocator state outside
  ``compress/kvcache.py`` (bypasses the refcount/COW invariants).
- PVU006 — jit static args that specialize on prompt-length-like
  values outside ``runtime/engine.py`` (the recompile-per-prompt stall
  chunked prefill deleted).
- PVU007 — ``device_put``/array creation of cache or arena leaves in
  ``runtime/``/``models/`` without ``NamedSharding``/
  ``with_sharding_constraint`` (implicit replication defeats the
  head-sharded arena's per-device footprint).

Findings are waivable per line with ``# positcheck: disable=PVU001``
(comma-separated ids, or ``all``).  The waiver must sit on the line the
finding points at or on the first line of the flagged statement.
"""

from .core import Finding, ModuleFile, Rule, run_paths  # noqa: F401
from .rules import ALL_RULES, rule_by_id  # noqa: F401
