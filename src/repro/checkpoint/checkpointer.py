"""Fault-tolerant checkpointing: atomic, async, keep-last-k, elastic.

* Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a crash
  mid-save never corrupts the latest checkpoint.
* Async: the serialization runs on a worker thread; ``wait()`` joins
  before the next save (real clusters overlap save with compute).
* Elastic: arrays are stored mesh-agnostic (full ndarray per leaf);
  ``restore(..., shardings=...)`` re-lays them out on ANY mesh, so a
  512-chip checkpoint restores onto 256 chips and vice versa
  (tests/test_checkpoint.py::test_elastic_remesh).
* Optional posit16 payload compression for f32 leaves (halves checkpoint
  bytes; the paper's codec as a storage format).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.convert import f32_to_posit, posit_to_f32
from repro.core.types import POSIT16

_SENTINEL = "checkpoint_complete.json"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 posit_payload: bool = False):
        self.dir = directory
        self.keep = keep
        self.posit_payload = posit_payload
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot ``tree`` at ``step`` (async unless blocking)."""
        self.wait()
        # materialize on host before handing to the thread
        leaves = [np.asarray(x) for x in jax.tree.leaves(tree)]
        paths = [self._path_str(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(tree)[0]]

        def work():
            tmp = os.path.join(self.dir, f"tmp.{step}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            arrays, meta = {}, {"step": step, "leaves": []}
            for i, (name, arr) in enumerate(zip(paths, leaves)):
                key = f"a{i}"
                entry = {"path": name, "dtype": str(arr.dtype),
                         "shape": list(arr.shape), "codec": "raw"}
                if self.posit_payload and arr.dtype == np.float32:
                    arr = np.asarray(
                        f32_to_posit(jnp.asarray(arr), POSIT16))
                    entry["codec"] = "posit16"
                arrays[key] = arr
                meta["leaves"].append(entry)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, _SENTINEL), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)                      # atomic publish
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        self.wait()
        steps = []
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_")
                    and os.path.exists(os.path.join(full, _SENTINEL))):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, step: int, tree_template, shardings=None):
        """Restore into the structure of ``tree_template``; place leaves
        with ``shardings`` (tree of NamedSharding) if given — this is the
        elastic re-mesh path."""
        self.wait()
        final = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(final, _SENTINEL)) as f:
            meta = json.load(f)
        data = np.load(os.path.join(final, "arrays.npz"))
        leaves = []
        for i, entry in enumerate(meta["leaves"]):
            arr = data[f"a{i}"]
            if entry["codec"] == "posit16":
                arr = np.asarray(posit_to_f32(jnp.asarray(arr), POSIT16))
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(tree_template)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return tree, meta["step"]

    # ------------------------------------------------------------------
    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    @staticmethod
    def _path_str(path):
        out = []
        for p in path:
            out.append(str(getattr(p, "key", getattr(p, "idx", p))))
        return "/".join(out)
