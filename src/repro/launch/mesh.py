"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module never
touches jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to materialize the placeholder devices.
"""
from __future__ import annotations

import warnings

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh is 2 pods = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """A mesh over whatever devices actually exist (examples / tests).

    ``model_parallel`` that does not divide the device count cannot
    factor an ``(n // mp, mp)`` mesh; it is rounded DOWN to the largest
    divisor of ``n`` (with a warning) instead of crashing
    ``jax.make_mesh``."""
    n = len(jax.devices())
    mp = max(1, min(int(model_parallel), n))
    while n % mp:
        mp -= 1
    if mp != model_parallel:
        warnings.warn(
            f"model_parallel={model_parallel} does not factor the "
            f"{n}-device host platform; rounding down to "
            f"model_parallel={mp}", stacklevel=2)
    return jax.make_mesh((n // mp, mp), ("data", "model"))
