"""Training launcher (runs on real devices — examples use small configs).

End-to-end: config -> mesh -> data pipeline -> pjit train step ->
supervised loop with async checkpoints, auto-resume, and the straggler
watchdog.  ``--arch`` accepts any assigned architecture id; ``--reduced``
shrinks it to a CPU-runnable model (the quickstart path).

  PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --reduced \
      --steps 300 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, Pipeline
from repro.launch.mesh import make_host_mesh
from repro.models import get_family
from repro.optim import adamw
from repro.runtime import sharding, train_loop
from repro.runtime.fault import StragglerWatchdog, TrainSupervisor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS,
                    default="gemma-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--data", choices=["synthetic", "bytes"],
                    default="synthetic")
    ap.add_argument("--corpus", default=None)
    ap.add_argument("--posit-moments", action="store_true",
                    help="store Adam first moments in posit16")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(compute_dtype="float32")
    cfg = dataclasses.replace(cfg, fsdp=False,
                              seq_shard_activations=False)

    mesh = make_host_mesh()
    fam = get_family(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr,
                                posit_moments=args.posit_moments)
    pipe = Pipeline(DataConfig(source=args.data, path=args.corpus), cfg,
                    args.batch, args.seq)

    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init(params, opt_cfg)
    step_fn = train_loop.make_train_step(cfg, opt_cfg,
                                         total_steps=args.steps)
    p_sh = sharding.param_shardings(params, mesh)
    params = jax.device_put(params, p_sh)
    jitted = jax.jit(step_fn)

    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    watchdog = StragglerWatchdog()
    supervisor = TrainSupervisor(ckpt, save_every=args.save_every,
                                 watchdog=watchdog)

    t_start = time.time()
    losses = []

    def one_step(state, step):
        params, opt_state = state
        batch = pipe.batch_at(step)
        params, opt_state, metrics = jitted(
            params, opt_state, batch, jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            dt = time.time() - t_start
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({dt:.1f}s)", flush=True)
        return params, opt_state

    state, executed = supervisor.run(
        state=(params, opt_state), step_fn=one_step,
        total_steps=args.steps)
    print(f"done: {executed} steps, final loss {losses[-1]:.4f}, "
          f"first loss {losses[0]:.4f}, "
          f"stragglers flagged {watchdog.stragglers}")
    return losses


if __name__ == "__main__":
    main()
