"""Attribute collective bytes to source ops (diagnosis tool for §Perf)."""
from __future__ import annotations

import re
import sys

from .hlo_cost import (_COLLECTIVES, _INSTR_RE, _type_numel_bytes,
                       parse_module, _multipliers)


def top_collectives(hlo_text: str, n: int = 15):
    comps = parse_module(hlo_text)
    mult, _ = _multipliers(comps)
    rows = []
    for cname, instrs in comps.items():
        k = mult.get(cname, 1.0) or 1.0
        for ins in instrs:
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                _, byts = _type_numel_bytes(ins.type_str)
                m = re.search(r'op_name="([^"]*)"', ins.rest)
                rows.append((k * byts, base, k, ins.type_str[:60],
                             (m.group(1) if m else "?")[:110]))
    rows.sort(reverse=True)
    return rows[:n]


if __name__ == "__main__":
    for b, op, k, t, name in top_collectives(open(sys.argv[1]).read()):
        print(f"{b:.3e}B x{k:<6.0f} {op:<18} {t:<50} {name}")
