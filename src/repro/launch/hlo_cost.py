"""Trip-count-aware cost analysis of the optimized (post-SPMD) HLO.

Why this exists: ``compiled.cost_analysis()`` counts every while-loop body
ONCE.  Our models lax.scan the layer stack (and flash-attention scans KV
blocks), so the built-in numbers undercount an 88-layer model by ~88x.
XLA annotates canonicalized loops with ``known_trip_count``, so we parse
the HLO module, build the computation call graph, propagate trip-count
multipliers, and accumulate:

  * flops        — dot instructions: 2 * |result| * |contracted dims|
                   (+1 flop/element for arithmetic/transcendental ops,
                   fusion bodies included);
  * hbm bytes    — XLA convention (operands + result) summed over
                   *top-level* instructions only: fusion bodies stay in
                   registers/VMEM, so only materialization points count;
  * collectives  — per-op-type bytes, trip-aware.

All numbers are per-chip (the module is the per-device SPMD program), so
GSPMD padding waste and resharding traffic are captured honestly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "c64": 8, "c128": 16,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(
    r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->.*{")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLSITE_RE = re.compile(
    r"(?:body|condition|calls|to_apply|branches)="
    r"\{?(%[\w.\-]+(?:,\s*%[\w.\-]+)*)\}?")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "tanh", "log", "log-plus-one",
    "rsqrt", "sqrt", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-even", "power", "atan2", "compare", "select", "and",
    "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "convert", "clamp", "remainder", "cosine",
    "sine", "logistic", "cbrt", "erf", "popcnt", "count-leading-zeros",
}
_REDUCERS = {"reduce", "reduce-window"}
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "fusion", "after-all", "domain",
    "partition-id", "replica-id", "iota",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _parse_dims(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _type_numel_bytes(type_str: str) -> Tuple[int, int]:
    elems = byts = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = _parse_dims(dims)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    rest: str
    operands: List[str]


def _parse_operands(rest: str) -> List[str]:
    """Names inside the first top-level parenthesized list."""
    depth, out, cur = 0, [], []
    for ch in rest:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                out.append("".join(cur))
                break
        if depth >= 1:
            cur.append(ch)
    if not out:
        return []
    names = re.findall(r"%([\w.\-]+)", out[0])
    return names


def parse_module(hlo_text: str):
    comps: Dict[str, List[_Instr]] = {}
    current: Optional[str] = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        # headers have no " = " assignment ("/*index=5*/" comments do
        # contain '=', so match the padded form)
        if m and " = " not in line.split("{")[0]:
            current = m.group(1)
            comps[current] = []
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, type_str, opcode, rest = mi.groups()
            comps[current].append(
                _Instr(name, type_str, opcode, rest,
                       _parse_operands("(" + rest)))
    return comps


def _multipliers(comps) -> Tuple[Dict[str, float], Dict[str, bool]]:
    mult = {c: 0.0 for c in comps}
    fused = {c: False for c in comps}
    entry_candidates = set(comps)
    callees = set()
    edges: List[Tuple[str, str, float, bool]] = []
    for cname, instrs in comps.items():
        for ins in instrs:
            trip = 1.0
            if ins.opcode == "while":
                m = _TRIP_RE.search(ins.rest)
                trip = float(m.group(1)) if m else 1.0
            for m in _CALLSITE_RE.finditer(ins.rest):
                for callee in re.split(r",\s*", m.group(1)):
                    callee = callee.lstrip("%")
                    if callee in comps:
                        edges.append((cname, callee, trip,
                                      ins.opcode == "fusion"))
                        callees.add(callee)
    for c in comps:
        if c not in callees:
            mult[c] = 1.0
    # propagate to fixpoint (call graph is a DAG; few iterations suffice)
    for _ in range(len(comps)):
        changed = False
        for src, dst, trip, is_fusion in edges:
            cand = mult[src] * trip
            if cand > mult[dst]:
                mult[dst] = cand
                changed = True
            if is_fusion and not fused[dst]:
                fused[dst] = True
                changed = True
            if fused[src] and not fused[dst]:
                fused[dst] = True
                changed = True
        if not changed:
            break
    return mult, fused


def analyze(hlo_text: str) -> dict:
    comps = parse_module(hlo_text)
    mult, fused = _multipliers(comps)

    flops = 0.0
    bytes_hbm = 0.0
    colls: Dict[str, float] = {}
    shapes: Dict[Tuple[str, str], str] = {}
    for cname, instrs in comps.items():
        for ins in instrs:
            shapes[(cname, ins.name)] = ins.type_str

    for cname, instrs in comps.items():
        k = mult.get(cname, 1.0)
        if k == 0.0:
            k = 1.0
        in_fusion = fused.get(cname, False)
        for ins in instrs:
            elems, byts = _type_numel_bytes(ins.type_str)
            # ---- flops
            if ins.opcode == "dot":
                contract = 1
                mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                               ins.rest)
                if mm and ins.operands:
                    lhs_type = shapes.get((cname, ins.operands[0]), "")
                    sm = _SHAPE_RE.search(lhs_type)
                    if sm:
                        dims = [int(d) for d in sm.group(2).split(",")
                                if d] or [1]
                        for ci in mm.group(1).split(","):
                            if ci:
                                contract *= dims[int(ci)]
                flops += k * 2.0 * elems * contract
            elif ins.opcode in _ELEMENTWISE:
                flops += k * elems
            elif ins.opcode in _REDUCERS and ins.operands:
                in_type = shapes.get((cname, ins.operands[0]), "")
                in_elems, _ = _type_numel_bytes(in_type)
                flops += k * in_elems
            # ---- bytes (top-level materializations only)
            if not in_fusion and ins.opcode not in _SKIP_BYTES:
                op_bytes = 0
                for op in ins.operands:
                    t = shapes.get((cname, op))
                    if t:
                        op_bytes += _type_numel_bytes(t)[1]
                bytes_hbm += k * (byts + op_bytes)
            # ---- collectives
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                colls[base] = colls.get(base, 0.0) + k * byts
    return {"flops": flops, "bytes": bytes_hbm, "collectives": colls}
