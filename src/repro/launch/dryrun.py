import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, the parameter/optimizer/
cache ShapeDtypeStruct trees with their NamedShardings, lowers the right
step function (train_step / prefill_step / serve_step), compiles it, and
records:

  * compiled.memory_analysis()  -> bytes/device (proves it fits)
  * compiled.cost_analysis()    -> HLO FLOPs / bytes for the roofline
  * collective bytes by op type -> parsed from the optimized HLO

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline table (benchmarks/roofline.py, EXPERIMENTS.md) reads them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES
from repro.launch import hlo_analysis, hlo_cost, specs
from repro.launch.mesh import make_production_mesh
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime import sharding, train_loop

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _serve_params_shape(cfg: ModelConfig, p_shape):
    """Quantize the weight dtypes for posit-weight serving cells."""
    if not cfg.weight_posit:
        return p_shape
    from repro.models.layers import pcfg
    store = pcfg(cfg.weight_posit).storage_dtype

    def one(path, leaf):
        name = sharding._path_str(path)
        quantizable = (name.endswith("/w") or name == "tok_embed"
                       or name.endswith("moe/wi") or name.endswith("moe/wg")
                       or name.endswith("moe/wo"))
        if quantizable and leaf.dtype == jnp.float32 and len(leaf.shape) >= 2:
            return jax.ShapeDtypeStruct(leaf.shape, store)
        return leaf

    return jax.tree_util.tree_map_with_path(one, p_shape)


def _ef_shardings(p_shape, mesh, cfg, n_pods):
    n_data = mesh.shape.get("data", 1)
    pspecs = sharding.param_specs(p_shape, mesh, fsdp=True, n_data=n_data)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, P("pod", *s)), pspecs)


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str) -> dict:
    spec = SHAPES[shape]
    cfg = configs.config_for_cell(arch, shape)
    if multi_pod:
        cfg = dataclasses.replace(cfg, batch_axes=("pod", "data"))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    n_pods = mesh.shape.get("pod", 1)

    p_shape = specs.params_shape(cfg)
    p_sh = sharding.param_shardings(p_shape, mesh, fsdp=cfg.fsdp)
    record = {"arch": arch, "shape": shape,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "kind": spec.kind, "ok": False}
    t0 = time.time()

    if spec.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        opt_shape = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), p_shape)
        opt_sh = sharding.param_shardings(opt_shape, mesh, fsdp=cfg.fsdp)
        batch_sds = specs.input_specs(cfg, spec)
        b_specs = sharding.batch_specs(batch_sds, mesh, cfg)
        b_sh = sharding.to_shardings(b_specs, mesh)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        step_sh = NamedSharding(mesh, P())
        compressed = multi_pod and bool(cfg.grad_compress)
        fn = train_loop.make_train_step(
            cfg, opt_cfg, n_pods=n_pods, compressed=compressed)
        metrics_sh = {"loss": step_sh, "grad_norm": step_sh}
        if compressed:
            ef_shape = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct((n_pods,) + l.shape,
                                               jnp.float32), p_shape)
            ef_sh = _ef_shardings(p_shape, mesh, cfg, n_pods)
            # pod-tiled batch: (n_pods, B/n_pods, ...)
            tiled_batch = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(
                    (n_pods, l.shape[0] // n_pods) + l.shape[1:], l.dtype),
                batch_sds)
            tb_sh = jax.tree.map(
                lambda l: NamedSharding(
                    mesh, P("pod", "data", *([None] * (len(l.shape) - 2)))),
                tiled_batch)
            jitted = jax.jit(fn, in_shardings=(p_sh, opt_sh, ef_sh, tb_sh,
                                               step_sh),
                             out_shardings=(p_sh, opt_sh, ef_sh, metrics_sh))
            args = (p_shape, opt_shape, ef_shape, tiled_batch, step_sds)
        else:
            jitted = jax.jit(fn, in_shardings=(p_sh, opt_sh, b_sh, step_sh),
                             out_shardings=(p_sh, opt_sh, metrics_sh))
            args = (p_shape, opt_shape, batch_sds, step_sds)

    elif spec.kind == "prefill":
        batch_sds = specs.input_specs(cfg, spec)
        b_sh = sharding.to_shardings(
            sharding.batch_specs(batch_sds, mesh, cfg), mesh)
        fn = train_loop.make_prefill_step(cfg)
        # §Perf: shard the *output* cache (batch + seq over the mesh) —
        # without out_shardings the compiler materializes it replicated
        cache_out_shape, logits_shape = jax.eval_shape(
            fn, p_shape, batch_sds)
        c_sh = sharding.to_shardings(
            sharding.cache_specs(cache_out_shape, mesh, cfg), mesh)
        l_sh = NamedSharding(mesh, sharding.filter_spec(
            P(sharding.batch_axes(spec.global_batch, mesh), "model"),
            logits_shape.shape, mesh))
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh),
                         out_shardings=(c_sh, l_sh))
        args = (p_shape, batch_sds)

    else:  # decode
        p_shape = _serve_params_shape(cfg, p_shape)
        p_sh = sharding.param_shardings(p_shape, mesh, fsdp=False)
        cache_shape = specs.cache_shape(cfg, spec)
        seq_shard = spec.global_batch == 1          # long-context cells
        c_specs = sharding.cache_specs(cache_shape, mesh, cfg,
                                       seq_axis_shard=seq_shard)
        c_sh = sharding.to_shardings(c_specs, mesh)
        tok_sds = specs.decode_token_spec(spec)
        tok_axes = sharding.batch_axes(spec.global_batch, mesh)
        tok_sh = NamedSharding(mesh, P(tok_axes))
        fn = train_loop.make_serve_step(cfg)
        logits_sh = NamedSharding(mesh, sharding.filter_spec(
            P(tok_axes, "model"), (spec.global_batch, cfg.vocab), mesh))
        jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, tok_sh),
                         out_shardings=(logits_sh, c_sh))
        args = (p_shape, cache_shape, tok_sds)

    with sharding.set_mesh(mesh):
        lowered = jitted.lower(*args)
        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    if mem is not None:
        record["memory"] = {
            "argument_bytes_per_device": int(mem.argument_size_in_bytes),
            "output_bytes_per_device": int(mem.output_size_in_bytes),
            "temp_bytes_per_device": int(mem.temp_size_in_bytes),
            "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                         + mem.temp_size_in_bytes),
        }
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    # trip-count-aware analysis (XLA's cost_analysis counts loop bodies
    # once — wrong by ~n_layers for scanned stacks; see hlo_cost.py)
    trip = hlo_cost.analyze(hlo_text)
    flops = float(trip["flops"])
    byts = float(trip["bytes"])
    colls = {k: int(v) for k, v in trip["collectives"].items()}
    record["cost"] = {
        "flops_per_chip": flops,
        "bytes_per_chip": byts,
        "xla_once_through_flops": float(cost.get("flops", 0.0)),
        "xla_once_through_bytes": float(cost.get("bytes accessed", 0.0)),
    }
    record["collectives_per_chip"] = colls
    record["roofline"] = hlo_analysis.roofline_terms(
        flops_per_chip=flops, bytes_per_chip=byts,
        coll_bytes_per_chip=float(sum(colls.values())), n_chips=n_chips)
    mf = hlo_analysis.model_flops(cfg, spec)
    record["model_flops_total"] = mf
    total_hlo = flops * n_chips
    record["useful_flop_ratio"] = (mf / total_hlo) if total_hlo else None
    record["ok"] = True

    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}__{shape}__{record['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--keep-going", action="store_true")
    args = ap.parse_args()

    cells = list(configs.all_cells()) if args.all else [
        (args.arch, s) for s in
        (configs.supported_shapes(args.arch) if args.shape is None
         else [args.shape])]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if multi else '16x16'}"
            try:
                rec = run_cell(arch, shape, multi, args.out)
                mem = rec.get("memory", {})
                print(f"[OK] {tag}: lower={rec['lower_s']}s "
                      f"compile={rec['compile_s']}s "
                      f"flops/chip={rec['cost']['flops_per_chip']:.3e} "
                      f"peak/dev={mem.get('peak_bytes_per_device', 0)/2**30:.2f}GiB "
                      f"dominant={rec['roofline']['dominant']}",
                      flush=True)
            except Exception:
                failures += 1
                print(f"[FAIL] {tag}", flush=True)
                traceback.print_exc()
                if not args.keep_going:
                    raise
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
