"""Serving launcher: the preallocated ring-buffer posit-cache engine.

Loads (or random-inits) a model, builds a ``repro.runtime.engine.Engine``
with a ``--max-len`` cache budget, prefills a batch of prompts (ragged
lengths supported for the transformer family via ``--ragged``), then
decodes the whole generation in one compiled ``lax.scan`` call.
``--kv-posit`` turns on the paper's KV compression; the report prints
actual vs f32-equivalent cache bytes.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
      --reduced --batch 4 --prompt-len 32 --gen 16 --kv-posit posit16 \
      --max-len 64 --temperature 0.7 --seed 0
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.compress.kvcache import cache_report
from repro.models import get_family
from repro.runtime.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS,
                    default="phi3-medium-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--ragged", action="store_true",
                    help="vary prompt lengths across the batch "
                         "(transformer family only)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="preallocated cache length "
                         "(default: prompt-len + gen)")
    ap.add_argument("--kv-posit", choices=["posit16", "posit8", "none"],
                    default="none")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 = softmax sampling")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(compute_dtype="float32")
    if args.kv_posit != "none":
        cfg = dataclasses.replace(cfg, kv_posit=args.kv_posit)

    fam = get_family(cfg)
    rng = np.random.default_rng(args.seed)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)

    if args.ragged:
        lens = rng.integers(max(2, args.prompt_len // 2),
                            args.prompt_len + 1, size=args.batch)
        prompts = [rng.integers(1, cfg.vocab, int(n)).tolist()
                   for n in lens]
    else:
        prompts = rng.integers(1, cfg.vocab,
                               size=(args.batch, args.prompt_len))

    kwargs = {}
    if cfg.family == "whisper":
        kwargs["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.n_visual_tokens:
        kwargs["visual"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_visual_tokens, cfg.d_model)), jnp.float32)

    max_len = args.max_len or (args.prompt_len + args.gen)
    engine = Engine(cfg, params, max_len=max_len,
                    temperature=args.temperature, seed=args.seed)

    t0 = time.time()
    cache, logits, lens = engine.prefill(prompts, **kwargs)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    rep = cache_report(cache)
    print(f"prefill: {args.batch} prompts (lens {lens.tolist()}) in "
          f"{t_prefill:.2f}s; cache bytes = {rep['bytes']:,} of "
          f"{rep['f32_bytes']:,} f32-equiv ({rep['ratio']:.2f}x, "
          f"kv_posit={cfg.kv_posit}, max_len={max_len})")

    t0 = time.time()
    res = engine.generate(prompts, args.gen, **kwargs)
    dt = time.time() - t0
    print(f"decode: {args.gen} steps in {dt:.2f}s "
          f"({args.gen * args.batch / max(dt, 1e-9):.1f} tok/s, "
          f"one compiled scan; includes prefill+compile on first call)")
    print("generated ids:\n", res.tokens)
    return res.tokens


if __name__ == "__main__":
    main()
