"""Serving launcher: the preallocated ring-buffer posit-cache engine.

Loads (or random-inits) a model, builds a ``repro.runtime.engine.Engine``
with a ``--max-len`` cache budget, prefills a batch of prompts (ragged
lengths supported for the transformer family via ``--ragged``), then
decodes the whole generation in one compiled ``lax.scan`` call.
``--kv-posit`` turns on the paper's KV compression; the report prints
actual vs f32-equivalent cache bytes.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
      --reduced --batch 4 --prompt-len 32 --gen 16 --kv-posit posit16 \
      --max-len 64 --temperature 0.7 --seed 0

``--continuous`` switches to the iteration-level scheduler
(``repro.runtime.scheduler``): ``--n-requests`` requests arrive on a
simulated Poisson trace (``--arrival-rate`` expected arrivals per decode
step), prompts/generation lengths are ragged, and ``--batch`` becomes
the slot-pool width.  Requests join and leave between fixed
``--chunk-size`` decode chunks (each one compiled dispatch); the report
prints goodput and p50/p99 request latency in decode steps.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
      --reduced --continuous --batch 4 --n-requests 16 \
      --arrival-rate 0.2 --chunk-size 8 --max-len 64

``--paged`` swaps the dense ``slots x max_len`` cache for the paged
block-table layout (``--block-size`` slots per block, ``--n-blocks``
arena size; 0 = worst case): rows allocate blocks as they grow and free
them at retirement, so peak cache memory tracks the tokens actually
resident instead of the worst case, and admission never compacts.  The
dense path stays selectable (omit ``--paged``) for A/B comparison.

``--prefix-cache`` (with ``--continuous --paged``) deduplicates shared
prompt prefixes: admission matches each prompt's leading full blocks
against a content-addressed index of resident blocks, borrows the hits
via refcounts and skips their prefill chunks; writes into borrowed
blocks copy-on-write first, so greedy token streams are unchanged.
``--prefix-share`` generates the matching trace — every prompt opens
with the same system prefix of that fractional length:

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
      --reduced --continuous --paged --prefix-cache --batch 4 \
      --n-requests 16 --prompt-len 32 --prefix-share 0.75 --block-size 4

``--chunked-prefill`` (with ``--continuous --paged``) routes prompts
through the decode lane in fixed ``--chunk-size``-token chunks, so ONE
compiled dispatch shape serves every request and the engine's compile
count stays flat no matter how ragged the prompt lengths are (implied
by ``--prefix-cache``).  ``--deadline-ms`` attaches a completion
deadline to every request — admission turns earliest-deadline-first
and, when the pool is full, the scheduler preempts the latest-deadline
row (releasing its blocks) to admit a more urgent one.  Deadlines are
converted to the decode-step simulation clock at ``MS_PER_STEP`` ms
per step (an assumed reference-hardware step time; the SIMULATED
schedule is what the deadline shapes, wall time per step varies by
host):

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
      --reduced --continuous --paged --chunked-prefill --batch 4 \
      --n-requests 16 --deadline-ms 400 --chunk-size 4

``--model-parallel N`` serves tensor-parallel over a host mesh
(``launch.mesh.make_host_mesh``): weights are placed by the
``runtime/sharding.py`` rule table and the paged block arena is
head-sharded over the 'model' axis, so each device holds
``1/N``-th of the KV content (the report prints per-device KV bytes).
Token streams are identical to the single-device run.  Multi-device
CPU hosts are forced with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set it before
launching); a degree that does not divide the device count rounds
down with a warning.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.compress.kvcache import cache_report
from repro.models import get_family
from repro.runtime.engine import Engine
from repro.runtime.scheduler import Scheduler

# assumed wall time of one decode step on the reference hardware, used
# only to convert --deadline-ms into the decode-step simulation clock
# (the schedule is simulated, so only the RATIO deadline/step matters)
MS_PER_STEP = 10.0


def poisson_trace(rng, n_requests, rate, vocab, prompt_len, gen):
    """Ragged request trace: Poisson arrivals (``rate`` expected requests
    per decode step), uniform prompt/generation lengths."""
    arrivals = np.cumsum(rng.exponential(1.0 / max(rate, 1e-9),
                                         size=n_requests))
    out = []
    for t in arrivals:
        plen = int(rng.integers(max(2, prompt_len // 2), prompt_len + 1))
        g = int(rng.integers(max(2, gen // 4), gen + 1))
        out.append((float(t), rng.integers(1, vocab, plen).tolist(), g))
    return out


def shared_prefix_trace(rng, n_requests, rate, vocab, prompt_len, gen,
                        share: float = 0.75):
    """Request trace where every prompt opens with the SAME system
    prefix: ``share`` of ``prompt_len`` tokens are drawn once and
    reused, the tail is per-request.  Arrivals/generation lengths match
    :func:`poisson_trace`'s model; this is the trace prefix caching is
    built for (the share ratio bounds its possible win)."""
    arrivals = np.cumsum(rng.exponential(1.0 / max(rate, 1e-9),
                                         size=n_requests))
    n_shared = max(1, int(prompt_len * share))
    prefix = rng.integers(1, vocab, n_shared).tolist()
    out = []
    for t in arrivals:
        tail = int(rng.integers(2, max(3, prompt_len - n_shared + 1)))
        g = int(rng.integers(max(2, gen // 4), gen + 1))
        out.append((float(t),
                    prefix + rng.integers(1, vocab, tail).tolist(), g))
    return out


def drive_trace(sched: Scheduler, trace, deadline_steps=None):
    """Feed a (arrival_step, prompt, gen) trace through a scheduler,
    advancing the simulation clock through idle gaps; returns
    ``{rid: Completion}`` keyed in trace order.  ``deadline_steps``
    attaches ``arrival + deadline_steps`` as every request's absolute
    deadline (EDF admission + preemption; ``None`` = best-effort)."""
    pending = list(trace)
    done = {}
    order = {}
    while pending or sched.has_work:
        while pending and pending[0][0] <= sched.steps_run:
            t, prompt, gen = pending.pop(0)
            rid = sched.submit(
                prompt, gen,
                deadline=None if deadline_steps is None
                else int(np.ceil(t)) + int(deadline_steps))
            order[rid] = len(order)
        if not sched.has_work:
            # idle: jump the decode-step clock to the next arrival
            sched.steps_run = max(sched.steps_run,
                                  int(np.ceil(pending[0][0])))
            continue
        for c in sched.step():
            done[c.rid] = c
    return done, order


def _build_engine(args, cfg, params, max_len):
    kernel = getattr(args, "decode_kernel", "gather")
    mesh = None
    if getattr(args, "model_parallel", 1) > 1:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(args.model_parallel)
    return Engine(cfg, params, max_len=max_len,
                  temperature=args.temperature, seed=args.seed,
                  paged=args.paged, block_size=args.block_size,
                  n_blocks=args.n_blocks,
                  decode_kernel=None if kernel == "gather" else kernel,
                  mesh=mesh)


def run_continuous(args, cfg, params):
    rng = np.random.default_rng(args.seed)
    # worst-case slot demand: prompt + gen - 1 cached tokens plus a full
    # chunk of frontier headroom (overshoot before retirement)
    max_len = args.max_len or (args.prompt_len + args.gen - 1 +
                               args.chunk_size)
    engine = _build_engine(args, cfg, params, max_len)
    sched = Scheduler(engine, n_slots=args.batch,
                      chunk_size=args.chunk_size,
                      prefix_cache=args.prefix_cache,
                      chunked_prefill=args.chunked_prefill)
    deadline_steps = None
    if args.deadline_ms > 0:
        deadline_steps = max(1, int(np.ceil(args.deadline_ms
                                            / MS_PER_STEP)))
    if args.prefix_share > 0:
        trace = shared_prefix_trace(rng, args.n_requests,
                                    args.arrival_rate, cfg.vocab,
                                    args.prompt_len, args.gen,
                                    share=args.prefix_share)
    else:
        trace = poisson_trace(rng, args.n_requests, args.arrival_rate,
                              cfg.vocab, args.prompt_len, args.gen)
    t0 = time.time()
    done, _ = drive_trace(sched, trace, deadline_steps=deadline_steps)
    dt = time.time() - t0
    rep = cache_report(sched.cache)

    useful = sum(len(c.tokens) for c in done.values())
    lat = np.array(sorted(c.latency_steps for c in done.values()))
    goodput = useful / max(sched.steps_run, 1)
    print(f"continuous: {len(done)} requests, {useful} tokens in "
          f"{sched.n_chunks} chunks ({sched.steps_run} decode steps, "
          f"{dt:.2f}s incl. compile)")
    print(f"  goodput {goodput:.2f} tok/step of a {args.batch}-slot pool "
          f"({useful / max(dt, 1e-9):.1f} tok/s wall); latency p50 "
          f"{np.percentile(lat, 50):.0f} p99 {np.percentile(lat, 99):.0f} "
          f"steps")
    print(f"  cache: {rep['bytes']:,} bytes of {rep['f32_bytes']:,} "
          f"f32-equiv ({rep['ratio']:.2f}x, kv_posit={cfg.kv_posit}, "
          f"max_len={max_len})")
    if args.paged:
        print(f"  paged: {sched.n_blocks} arena blocks x "
              f"{sched.block_size} slots (dense worst case "
              f"{args.batch * sched.table_width}); peak in use "
              f"{sched.pool.peak_in_use}, peak committed "
              f"{sched.peak_committed}")
    if engine.mesh is not None:
        mp = engine.mesh.shape.get("model", 1)
        print(f"  sharded: mesh {dict(engine.mesh.shape)}; KV per "
              f"device {rep['per_device_bytes']:,} of {rep['bytes']:,} "
              f"bytes (model_parallel={mp}); step wall p50 "
              f"{sched.stats['step_wall_p50_ms']:.1f} ms p99 "
              f"{sched.stats['step_wall_p99_ms']:.1f} ms")
    if sched.chunked:
        print(f"  chunked prefill: {sched.prefill_tokens} prompt tokens "
              f"through the decode lane in {args.chunk_size}-token "
              f"chunks; {engine.n_compiles} compiled programs "
              f"(flat across prompt lengths)")
    if deadline_steps is not None:
        missed = sum(1 for c in done.values()
                     if c.finished_step > c.arrival_step + deadline_steps)
        print(f"  deadlines: {args.deadline_ms:.0f} ms "
              f"({deadline_steps} steps at {MS_PER_STEP:.0f} ms/step); "
              f"{len(done) - missed}/{len(done)} met, "
              f"{sched.n_preempted} preemptions")
    if args.prefix_cache:
        print(f"  prefix cache: {sched.prefix_hits}/{len(done)} "
              f"admissions hit, {sched.prefix_matched_tokens} prompt "
              f"tokens served from cache ({sched.prefill_tokens} "
              f"prefilled), {sched.n_cow} COW copies, "
              f"{sched.n_evicted} evictions; peak committed "
              f"physical {sched.peak_committed} vs logical "
              f"{sched.peak_logical} blocks")
    return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS,
                    default="phi3-medium-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--ragged", action="store_true",
                    help="vary prompt lengths across the batch "
                         "(transformer family only)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="preallocated cache length (default: "
                         "prompt-len + gen for one-shot, plus "
                         "chunk-size - 1 headroom with --continuous)")
    ap.add_argument("--kv-posit", choices=["posit16", "posit8", "none"],
                    default="none")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 = softmax sampling")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: requests arrive on a "
                         "simulated Poisson trace and join/leave between "
                         "decode chunks (transformer family only)")
    ap.add_argument("--arrival-rate", type=float, default=0.2,
                    help="expected request arrivals per decode step "
                         "(with --continuous)")
    ap.add_argument("--n-requests", type=int, default=16,
                    help="trace length (with --continuous)")
    ap.add_argument("--chunk-size", type=int, default=8,
                    help="decode steps between scheduling rounds "
                         "(with --continuous)")
    ap.add_argument("--paged", action="store_true",
                    help="paged block-table KV cache (transformer "
                         "family only); omit for the dense layout")
    ap.add_argument("--block-size", type=int, default=16,
                    help="cache slots per arena block (with --paged)")
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="arena size in blocks (with --paged; "
                         "0 = worst case, never out of blocks)")
    ap.add_argument("--decode-kernel", choices=["gather", "fused"],
                    default="gather",
                    help="paged decode attention path (with --paged): "
                         "'gather' materializes per-row KV via "
                         "paged_gather then attends in jnp; 'fused' "
                         "walks the block table inside one Pallas "
                         "kernel (posit decode + online softmax "
                         "in-kernel), token-identical with ~3-7x fewer "
                         "decode KV bytes")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-addressed prefix sharing with "
                         "copy-on-write block tables (with --continuous "
                         "--paged): admissions borrow already-resident "
                         "prompt blocks and prefill only the unmatched "
                         "suffix; greedy token streams are unchanged")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="feed prompts through the decode lane in fixed "
                         "chunk-size-token chunks (with --continuous "
                         "--paged): one compiled dispatch shape serves "
                         "every request, so the engine never "
                         "jit-specializes on a prompt length "
                         "(implied by --prefix-cache)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="with --continuous: per-request completion "
                         "deadline in milliseconds, converted to the "
                         "decode-step simulation clock at MS_PER_STEP "
                         "ms per step; drives EDF admission and "
                         "preemption-by-block-release (0 = best-effort "
                         "FIFO)")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="tensor-parallel degree over a host device "
                         "mesh: weights shard by the runtime/sharding "
                         "rule table and the paged KV arena shards its "
                         "head axis over 'model', so per-device KV "
                         "bytes drop ~linearly; token streams are "
                         "identical to the single-device run (force "
                         "devices on CPU with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--prefix-share", type=float, default=0.0,
                    help="with --continuous: fraction of each prompt "
                         "drawn from ONE shared system prefix (0 = fully "
                         "independent Poisson prompts); the share ratio "
                         "bounds the possible prefix-cache win")
    args = ap.parse_args(argv)
    if args.prefix_cache and not (args.continuous and args.paged):
        ap.error("--prefix-cache requires --continuous --paged")
    if args.chunked_prefill and not (args.continuous and args.paged):
        ap.error("--chunked-prefill requires --continuous --paged")
    if args.deadline_ms > 0 and not args.continuous:
        ap.error("--deadline-ms requires --continuous")
    if args.decode_kernel == "fused" and not args.paged:
        ap.error("--decode-kernel fused requires --paged")

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(compute_dtype="float32")
    if args.kv_posit != "none":
        cfg = dataclasses.replace(cfg, kv_posit=args.kv_posit)

    fam = get_family(cfg)
    rng = np.random.default_rng(args.seed)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)

    if args.continuous:
        return run_continuous(args, cfg, params)

    if args.ragged:
        lens = rng.integers(max(2, args.prompt_len // 2),
                            args.prompt_len + 1, size=args.batch)
        prompts = [rng.integers(1, cfg.vocab, int(n)).tolist()
                   for n in lens]
    else:
        prompts = rng.integers(1, cfg.vocab,
                               size=(args.batch, args.prompt_len))

    kwargs = {}
    if cfg.family == "whisper":
        kwargs["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.n_visual_tokens:
        kwargs["visual"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_visual_tokens, cfg.d_model)), jnp.float32)

    max_len = args.max_len or (args.prompt_len + args.gen)
    engine = _build_engine(args, cfg, params, max_len)

    t0 = time.time()
    cache, logits, lens = engine.prefill(
        prompts, reserve_tokens=args.gen - 1, **kwargs)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    rep = cache_report(cache)
    print(f"prefill: {args.batch} prompts (lens {lens.tolist()}) in "
          f"{t_prefill:.2f}s; cache bytes = {rep['bytes']:,} of "
          f"{rep['f32_bytes']:,} f32-equiv ({rep['ratio']:.2f}x, "
          f"kv_posit={cfg.kv_posit}, max_len={max_len})")

    t0 = time.time()
    res = engine.generate(prompts, args.gen, **kwargs)
    dt = time.time() - t0
    print(f"decode: {args.gen} steps in {dt:.2f}s "
          f"({args.gen * args.batch / max(dt, 1e-9):.1f} tok/s, "
          f"one compiled scan; includes prefill+compile on first call)")
    print("generated ids:\n", res.tokens)
    return res.tokens


if __name__ == "__main__":
    main()
