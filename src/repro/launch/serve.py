"""Serving launcher: batched prefill + decode with posit KV cache.

Loads (or random-inits) a model, prefills a batch of prompts, then decodes
greedily.  ``--kv-posit`` turns on the paper's KV compression; the report
prints cache bytes with and without it.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
      --reduced --batch 4 --prompt-len 32 --gen 16 --kv-posit posit16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.compress.kvcache import cache_bytes
from repro.models import get_family


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS,
                    default="phi3-medium-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-posit", choices=["posit16", "posit8", "none"],
                    default="none")
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(compute_dtype="float32")
    if args.kv_posit != "none":
        cfg = dataclasses.replace(cfg, kv_posit=args.kv_posit)

    fam = get_family(cfg)
    rng = np.random.default_rng(0)
    params = fam.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(
        rng.integers(1, cfg.vocab, size=(args.batch, args.prompt_len)),
        jnp.int32)
    kwargs = {}
    if cfg.family == "whisper":
        kwargs["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.n_visual_tokens:
        kwargs["visual"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_visual_tokens, cfg.d_model)), jnp.float32)

    t0 = time.time()
    prefill = jax.jit(lambda p, t: fam.prefill(p, t, cfg, **kwargs))
    cache, logits = prefill(params, tokens)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"cache bytes = {cache_bytes(cache):,} "
          f"(kv_posit={cfg.kv_posit})")

    decode = jax.jit(lambda p, c, t: fam.decode_step(p, c, t, cfg))
    out_tokens = [jnp.argmax(logits, -1).astype(jnp.int32)]
    t0 = time.time()
    for _ in range(args.gen):
        logits, cache = decode(params, cache, out_tokens[-1])
        out_tokens.append(jnp.argmax(logits, -1).astype(jnp.int32))
    jax.block_until_ready(out_tokens[-1])
    dt = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"decode: {args.gen} steps in {dt:.2f}s "
          f"({args.gen * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print("generated ids:\n", gen)
    return gen


if __name__ == "__main__":
    main()
