"""Post-SPMD HLO analysis: collective bytes + roofline terms.

``compiled.cost_analysis()`` gives HLO FLOPs and bytes, but collective
traffic must be read out of the optimized HLO text: we sum the result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async ``-start`` variants counted once, ``-done``
skipped).  The compiled module is the per-device SPMD program, so the
sums are per-chip; totals multiply by the chip count.

Hardware model (TPU v5e-class, per assignment):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}

_COLL = r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
# "<result-type(s)> <opcode>(" — operands are %-prefixed so they don't match
_LINE_RE = re.compile(r"=\s+(.*?)\s" + _COLL + r"(-start)?\(")
_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-chip bytes moved by each collective type (result sizes)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _LINE_RE.search(line)
        if not m:
            continue
        type_str, op, _ = m.groups()
        out[op] = out.get(op, 0) + _shape_bytes(type_str)
    return out


def roofline_terms(*, flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float, n_chips: int) -> dict:
    """The three roofline terms in seconds (assignment formulas, applied
    to totals: total_X / (chips * rate) == per-chip X / rate)."""
    compute_s = flops_per_chip / PEAK_FLOPS
    memory_s = bytes_per_chip / HBM_BW
    collective_s = coll_bytes_per_chip / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    return {**terms, "dominant": dominant.replace("_s", ""),
            "total_flops": flops_per_chip * n_chips,
            "total_bytes": bytes_per_chip * n_chips}


def model_flops(cfg, spec) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE): the useful-work
    yardstick against compiled HLO FLOPs."""
    n_params = active_param_count(cfg)
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n_params * tokens
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n_params * tokens
    tokens = spec.global_batch                        # decode: 1 new token
    return 2.0 * n_params * tokens


def active_param_count(cfg) -> float:
    """Per-token-active parameter count (MoE counts top_k experts)."""
    d, v, l_n = cfg.d_model, cfg.vocab, cfg.n_layers
    if cfg.family == "rwkv6":
        d_att = cfg.n_heads * cfg.head_dim
        per_layer = 4 * d * d_att + d_att * d + 2 * d * cfg.d_ff + d * d
        return v * d * 2 + l_n * per_layer
    if cfg.family == "whisper":
        att = 4 * d * cfg.n_heads * cfg.head_dim
        per_dec = 2 * att + 2 * d * cfg.d_ff
        per_enc = att + 2 * d * cfg.d_ff
        return v * d + cfg.n_layers * per_dec + \
            (cfg.encoder_layers or cfg.n_layers) * per_enc
    # transformer / hymba
    if cfg.mla:
        qh = cfg.qk_nope_dim + cfg.qk_rope_dim
        attn = (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qh +
                d * (cfg.kv_lora_rank + cfg.qk_rope_dim) +
                cfg.kv_lora_rank * cfg.n_heads *
                (cfg.qk_nope_dim + cfg.v_head_dim) +
                cfg.n_heads * cfg.v_head_dim * d)
    else:
        attn = d * cfg.n_heads * cfg.head_dim * 2 + \
            d * cfg.n_kv_heads * cfg.head_dim * 2
    if cfg.is_moe:
        ffn = 3 * d * cfg.d_ff_expert * cfg.top_k + d * cfg.n_experts
    else:
        ffn = 3 * d * cfg.d_ff
    if cfg.family == "hymba":
        hs, p_dim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        ssm = d * (2 * hs * p_dim + 2 * n + hs) + hs * p_dim * d
        per_layer = attn + ffn + ssm
    else:
        per_layer = attn + ffn
    embed = v * d * (1 if cfg.tie_embeddings else 2)
    return embed + l_n * per_layer
