"""ShapeDtypeStruct stand-ins for every (arch x shape) cell.

``input_specs`` builds the *data* inputs of the lowered step; parameter /
optimizer / cache trees are produced with jax.eval_shape against the
model's init functions — nothing here allocates device memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models import get_family
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    """Data-batch ShapeDtypeStructs for a cell (train/prefill kinds)."""
    b, s = spec.global_batch, spec.seq_len
    out = {"tokens": SDS((b, s), jnp.int32)}
    if cfg.family == "whisper":
        out["frames"] = SDS((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.n_visual_tokens:
        out["visual"] = SDS((b, cfg.n_visual_tokens, cfg.d_model),
                            jnp.float32)
    return out


def params_shape(cfg: ModelConfig):
    fam = get_family(cfg)
    return jax.eval_shape(
        lambda k: fam.init_params(k, cfg), jax.random.PRNGKey(0))


def cache_shape(cfg: ModelConfig, spec: ShapeSpec):
    fam = get_family(cfg)
    return jax.eval_shape(
        lambda: fam.init_cache(cfg, spec.global_batch, spec.seq_len))


def decode_token_spec(spec: ShapeSpec):
    return SDS((spec.global_batch,), jnp.int32)
