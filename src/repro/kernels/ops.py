"""Shape-polymorphic jit wrappers around the Pallas kernels.

These are what the rest of the framework calls: they accept arbitrary
array ranks, pad to tile boundaries, dispatch to the kernel, and undo the
padding.  ``interpret`` defaults to True because this container is
CPU-only; on a real TPU runtime pass ``interpret=False`` (the launcher
flag ``--pallas=native`` does this).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.types import PositConfig
from . import posit_codec, posit_dot, posit_ew, posit_gemm, posit_qgemm
# the fused paged-decode attention entries are cache-layout specific
# (block arenas + tables), not tile-shape polymorphic like the wrappers
# below — no padding shim to add, so they re-export as-is to keep one
# public kernel surface
from .posit_paged_attn import (paged_decode_attention,        # noqa: F401
                               paged_decode_attention_mla,    # noqa: F401
                               paged_decode_kv_bytes)         # noqa: F401


def _as_2d(x):
    """Flatten to (rows, cols) with cols = trailing dim (padded separately)."""
    if x.ndim == 0:
        return x.reshape(1, 1), x.shape
    if x.ndim == 1:
        return x.reshape(1, -1), x.shape
    return x.reshape(-1, x.shape[-1]), x.shape


def _pad_to(x, bm, bn):
    m, n = x.shape
    pm = (-m) % bm
    pn = (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x, m, n


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def quantize(x, cfg: PositConfig, interpret: bool = True):
    """f32 array (any rank) -> posit patterns, via the codec kernel."""
    x2, shape = _as_2d(jnp.asarray(x, jnp.float32))
    bm, bn = posit_codec.DEFAULT_BLOCK
    bm = min(bm, x2.shape[0])
    bn = min(bn, x2.shape[1])
    xp, m, n = _pad_to(x2, bm, bn)
    out = posit_codec.quantize_2d(xp, cfg, block=(bm, bn),
                                  interpret=interpret)
    return out[:m, :n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def dequantize(p, cfg: PositConfig, interpret: bool = True):
    """posit patterns (any rank) -> f32 array, via the codec kernel."""
    p2, shape = _as_2d(jnp.asarray(p))
    bm, bn = posit_codec.DEFAULT_BLOCK
    bm = min(bm, p2.shape[0])
    bn = min(bn, p2.shape[1])
    pp, m, n = _pad_to(p2, bm, bn)
    out = posit_codec.dequantize_2d(pp, cfg, block=(bm, bn),
                                    interpret=interpret)
    return out[:m, :n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def gemm(a, w_patterns, cfg: PositConfig, interpret: bool = True):
    """f32 (..., K) @ posit (K, N) -> f32 (..., N)."""
    a2, shape = _as_2d(jnp.asarray(a, jnp.float32))
    k, n = w_patterns.shape
    bm, bk, bn = posit_gemm.DEFAULT_BLOCKS
    bm = min(bm, a2.shape[0])
    bk = min(bk, k)
    bn = min(bn, n)
    ap, m, _ = _pad_to(a2, bm, bk)
    wp, _, _ = _pad_to(w_patterns, bk, bn)
    out = posit_gemm.posit_gemm(ap, wp, cfg, blocks=(bm, bk, bn),
                                interpret=interpret)
    return out[:m, :n].reshape(shape[:-1] + (n,))


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def dot(a_patterns, b_patterns, cfg: PositConfig, interpret: bool = True):
    """Bit-exact PVU dot product over the trailing axis, any rank.

    Operands broadcast like jnp (a rank-1 vector against a batched
    stack works); the result drops the contracted axis: (L,) -> scalar,
    (R, L) -> (R,), (B, R, L) -> (B, R).  Reduction length is unbounded
    (streamed through the K-tiled quire kernel, one rounding total).
    """
    a = jnp.asarray(a_patterns)
    b = jnp.asarray(b_patterns)
    if a.ndim == 0 or b.ndim == 0:
        raise ValueError("dot needs rank >= 1 operands (a reduction axis)")
    shape = jnp.broadcast_shapes(a.shape, b.shape)
    a = jnp.broadcast_to(a, shape).astype(cfg.storage_dtype)
    b = jnp.broadcast_to(b, shape).astype(cfg.storage_dtype)
    r = math.prod(shape[:-1])     # explicit: -1 can't infer past 0-dims
    if r == 0 or shape[-1] == 0:  # empty quire -> posit zero pattern
        return jnp.zeros(shape[:-1], cfg.storage_dtype)
    a2 = a.reshape(r, shape[-1])
    b2 = b.reshape(r, shape[-1])
    out = posit_dot.vpdot_rows(a2, b2, cfg, interpret=interpret)
    return out.reshape(shape[:-1])


def dot_rows(a_patterns, b_patterns, cfg: PositConfig,
             interpret: bool = True):
    """Bit-exact PVU dot product per row: (..., L) -> (...,).

    Historic name for :func:`dot` (originally (R, L)-only); now fully
    shape-polymorphic — rank-1 vectors and batched leading dims included.
    """
    return dot(a_patterns, b_patterns, cfg, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def pgemm(a_patterns, w_patterns, cfg: PositConfig,
          interpret: bool = True):
    """Bit-exact posit matmul: posit (..., K) @ posit (K, N) -> posit
    (..., N), one quire rounding per output element.

    The posit-in -> posit-out counterpart of :func:`gemm` (which
    dequantizes and rounds per k-tile in f32 on the MXU): use ``pgemm``
    for numerics audits, ``gemm`` for throughput.
    """
    a = jnp.asarray(a_patterns).astype(cfg.storage_dtype)
    w = jnp.asarray(w_patterns).astype(cfg.storage_dtype)
    if w.ndim != 2:
        raise ValueError(f"pgemm weights must be (K, N), got {w.shape}")
    if a.ndim == 0:
        raise ValueError("pgemm needs rank >= 1 activations")
    k, n = w.shape
    if a.shape[-1] != k:
        raise ValueError(
            f"pgemm contraction mismatch: {a.shape} @ {w.shape}")
    a2 = a.reshape(math.prod(a.shape[:-1]), k)
    out = posit_qgemm.posit_qgemm(a2, w, cfg, interpret=interpret)
    return out.reshape(a.shape[:-1] + (n,))


# ---------------------------------------------------------------------------
# Fused elementwise PVU ops (posit patterns in -> posit patterns out)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("cfg", "op", "div_mode", "interpret"))
def _elementwise(a, b, cfg: PositConfig, op: str, div_mode: str = "nr3",
                 interpret: bool = True):
    """Shared pad-to-block wrapper: broadcast, flatten to 2D, dispatch."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    shape = jnp.broadcast_shapes(a.shape, b.shape)
    a = jnp.broadcast_to(a, shape).astype(cfg.storage_dtype)
    b = jnp.broadcast_to(b, shape).astype(cfg.storage_dtype)
    a2, _ = _as_2d(a)
    b2, _ = _as_2d(b)
    bm, bn = posit_ew.DEFAULT_BLOCK
    bm = min(bm, a2.shape[0])
    bn = min(bn, a2.shape[1])
    ap, m, n = _pad_to(a2, bm, bn)
    bp, _, _ = _pad_to(b2, bm, bn)
    out = posit_ew.elementwise_2d(ap, bp, cfg, op, div_mode=div_mode,
                                  block=(bm, bn), interpret=interpret)
    return out[:m, :n].reshape(shape)


def vadd(a, b, cfg: PositConfig, interpret: bool = True):
    """Fused posit add: patterns (any rank, broadcastable) -> patterns."""
    return _elementwise(a, b, cfg, "add", interpret=interpret)


def vsub(a, b, cfg: PositConfig, interpret: bool = True):
    """Fused posit subtract on patterns."""
    return _elementwise(a, b, cfg, "sub", interpret=interpret)


def vmul(a, b, cfg: PositConfig, interpret: bool = True):
    """Fused posit multiply on patterns."""
    return _elementwise(a, b, cfg, "mul", interpret=interpret)


def vdiv(a, b, cfg: PositConfig, mode: str = "nr3",
         interpret: bool = True):
    """Fused posit divide on patterns.

    mode='nr3' is the paper-faithful Newton-Raphson divider;
    mode='exact' the beyond-paper exactly-rounded restoring divider.
    """
    return _elementwise(a, b, cfg, "div", div_mode=mode,
                        interpret=interpret)
