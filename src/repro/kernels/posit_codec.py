"""Pallas TPU kernel: fused posit quantize / dequantize (the PVU codec).

This is the framework's bandwidth-boundary kernel: gradients crossing the
pod interconnect, weight tiles feeding the MXU, and KV-cache blocks all
pass through it.  Elementwise over VMEM tiles; the bit manipulation runs
on the VPU (8x128 lanes), which is exactly the "vector posit unit"
adaptation of the paper (DESIGN.md §2).

Target: TPU (compiled via pl.pallas_call with explicit BlockSpecs).
Validation: interpret=True on CPU against ``ref.py`` / the golden model.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.convert import f32_to_posit, posit_to_f32
from repro.core.types import PositConfig

# VPU-aligned default tile: 8 sublanes x 128 lanes times a few registers.
DEFAULT_BLOCK = (256, 512)


def _quant_kernel(x_ref, o_ref, *, cfg: PositConfig):
    o_ref[...] = f32_to_posit(x_ref[...], cfg).astype(o_ref.dtype)


def _dequant_kernel(p_ref, o_ref, *, cfg: PositConfig):
    o_ref[...] = posit_to_f32(p_ref[...].astype(jnp.uint32), cfg)


def _grid(shape, block):
    bm = min(block[0], shape[0])
    bn = min(block[1], shape[1])
    return (pl.cdiv(shape[0], bm), pl.cdiv(shape[1], bn)), (bm, bn)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "block", "interpret"))
def quantize_2d(x, cfg: PositConfig, block=DEFAULT_BLOCK, interpret=True):
    """f32 (M, N) -> posit patterns (M, N) in cfg.storage_dtype."""
    grid, (bm, bn) = _grid(x.shape, block)
    return pl.pallas_call(
        functools.partial(_quant_kernel, cfg=cfg),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, cfg.storage_dtype),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "block", "interpret"))
def dequantize_2d(p, cfg: PositConfig, block=DEFAULT_BLOCK, interpret=True):
    """posit patterns (M, N) -> f32 (M, N)."""
    grid, (bm, bn) = _grid(p.shape, block)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, cfg=cfg),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(p.shape, jnp.float32),
        interpret=interpret,
    )(p)
