"""Pallas TPU kernel: fused paged-decode attention (posit KV in-kernel).

The serving engine's paged decode previously ran in two host-visible
passes: ``layers.paged_gather`` materialized every row's blocks into a
contiguous virtual cache, then ``layers.decode_attention`` dequantized
the WHOLE cache to f32/bf16 before computing a single score — exactly
the IEEE round-trip the paper's PVU argument is against.  This kernel
fuses the walk: a sequential grid dimension steps through each row's
block table, every block's posit8/16 K/V patterns decode on the VPU
inside VMEM (the same ``core.convert`` bit manipulation the codec
kernel ``posit_codec.py`` runs), and the online-softmax state (running
max ``m``, denominator ``l``, accumulator ``acc``) is carried across
table slots in VMEM scratch — the streaming pattern ``posit_dot.py``
uses for the K-tiled quire.  KV bytes are read from HBM exactly once,
as patterns: half the bytes of an f16 cache for posit16, a quarter for
posit8, with zero host-visible gather or dequantized materialization
(:func:`paged_decode_kv_bytes` is the analytic ledger both ends of
``bench_serve.py``'s comparison report).

Masking is resolved ENTIRELY in-kernel from the scalar-prefetched
block tables and frontiers: sentinel table entries (``id >= n_blocks``)
contribute nothing even though their DMA clamps into an arbitrary real
block, and the per-slot absolute positions ``apos`` (the caller builds
them with ``layers.paged_positions``; ``-1`` marks dead slots) carry
the ragged-length and sliding-window-ring validity.  A row with NO
valid slot — a preempted scheduler slot whose table is all sentinels —
produces exact zeros, the same all-masked guard ``decode_attention``
applies (``p`` is zeroed where invalid, so ``l == 0`` instead of a
uniform average of garbage).

Grid: ``(B, W)`` with the table-walk dimension sequential
(``dimension_semantics=("arbitrary",)``), so the carried scratch is
legal; block ``tables[b, w]`` of the arena is DMA'd per step via a
scalar-prefetch BlockSpec index map — no gather copy ever exists.

Target: TPU (compiled); validation: interpret=True on CPU (the
container default), bit-for-bit against ``posit_codec.py``'s decode
because both call the same ``core.convert.posit_to_f32``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.convert import posit_to_f32
from repro.core.types import PositConfig

from ._compat import CompilerParams as _CompilerParams

_NEG = -1e30


def _decode_block(x, pcfg: Optional[PositConfig]):
    """One block of KV, patterns -> f32 (or a float cache, cast)."""
    if pcfg is None:
        return x.astype(jnp.float32)
    return posit_to_f32(x.astype(jnp.uint32), pcfg)


def _slot_valid(tables_ref, lens_ref, apos, *, nb: int, window: int):
    """In-kernel validity of each slot of the current block: row-local
    position in ``[0, lens]`` (the frontier's just-written token is
    visible), inside the sliding window when one is set, and never
    through a sentinel table entry."""
    b, w = pl.program_id(0), pl.program_id(1)
    cl = lens_ref[b] + 1
    valid = (apos >= 0) & (apos < cl)
    if window:
        valid &= apos >= cl - window
    return valid & (tables_ref[b, w] < nb)


def _online_update(s, valid, v, m_ref, l_ref, acc_ref, contract: str):
    """One table-slot step of the carried online softmax.

    ``s``: (..., bs) f32 scores; ``valid``: (bs,) bool; ``v``: (bs, ...)
    f32 values.  Invalid slots are zeroed in ``p`` (not just pushed to
    ``exp(_NEG - m)``), so a row whose every slot is masked keeps
    ``l == 0`` and finalizes to zeros — the all-masked guard.  With at
    least one valid slot the zeroing is a no-op: ``m`` is finite and
    the masked ``exp`` already underflowed to exactly 0.0.
    """
    s = jnp.where(valid, s, _NEG)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
        contract, p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _paged_attn_kernel(tables_ref, lens_ref, q_ref, apos_ref, k_ref, v_ref,
                       o_ref, m_ref, l_ref, acc_ref, *,
                       pcfg: Optional[PositConfig], nw: int, nb: int,
                       window: int):
    """Dense/GQA lane: one (batch row, table slot) step."""
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, _NEG, m_ref.dtype)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                    # (G, R, D) pre-scaled
    k = _decode_block(k_ref[0], pcfg)               # (bs, G, D)
    v = _decode_block(v_ref[0], pcfg)               # (bs, G, Dv)
    s = jnp.einsum("grd,tgd->grt", q, k,
                   preferred_element_type=jnp.float32)
    valid = _slot_valid(tables_ref, lens_ref, apos_ref[0, 0],
                        nb=nb, window=window)[None, None, :]
    _online_update(s, valid, v, m_ref, l_ref, acc_ref, "grt,tgv->grv")

    @pl.when(w == nw - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def _paged_attn_mla_kernel(tables_ref, lens_ref, qc_ref, qr_ref, apos_ref,
                           c_ref, r_ref, o_ref, m_ref, l_ref, acc_ref, *,
                           pcfg: Optional[PositConfig], nw: int, nb: int,
                           scale: float):
    """MLA lane: absorbed-matrix attention in the compressed latent
    space.  K is the in-kernel concatenation of the latent (``c``) and
    decoupled-RoPE (``r``) arenas; V IS the latent block, so the
    context accumulates in latent space (the caller applies ``wuv``)."""
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, _NEG, m_ref.dtype)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = _decode_block(c_ref[0], pcfg)               # (bs, rank)
    r = _decode_block(r_ref[0], pcfg)               # (bs, rope)
    # leading singleton keeps the carried scratch 2-D/3-D (TPU layout)
    s = (jnp.einsum("ghr,tr->ght", qc_ref[...], c,
                    preferred_element_type=jnp.float32) +
         jnp.einsum("ghd,td->ght", qr_ref[...], r,
                    preferred_element_type=jnp.float32)) * scale
    valid = _slot_valid(tables_ref, lens_ref, apos_ref[0, 0],
                        nb=nb, window=0)[None, None, :]
    _online_update(s, valid, c, m_ref, l_ref, acc_ref, "ght,tr->ghr")

    @pl.when(w == nw - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)[..., None]
                      ).astype(o_ref.dtype)


def _table_walk_specs(tables, apos, arena_specs, out_block, scratch):
    """Shared grid spec: (B, W) grid, W sequential; tables and lens are
    scalar-prefetched so the arena BlockSpecs can DMA ``tables[b, w]``
    (sentinels clamp; the kernel masks their contribution)."""
    b, w = tables.shape
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, w),
        in_specs=arena_specs,
        out_specs=pl.BlockSpec(out_block, lambda b, w, tab, ln: (b,) + (0,) * (len(out_block) - 1)),
        scratch_shapes=scratch,
    )


def _block_index(nb):
    """Index map for an arena operand: table entry, sentinel-clamped
    (the kernel's validity mask excludes whatever the clamp aliases)."""
    def index(b, w, tab, ln, *, _nd):
        return (jnp.minimum(tab[b, w], nb - 1),) + (0,) * (_nd - 1)
    return index


@functools.partial(jax.jit,
                   static_argnames=("pcfg", "window", "interpret"))
def paged_decode_attention(q, k_arena, v_arena, tables, apos, lens, *,
                           pcfg: Optional[PositConfig] = None,
                           window: int = 0, interpret: bool = True):
    """Fused paged decode attention (dense/GQA and sliding-window lanes).

    q: (B, G, R, D) f32, already scaled by ``D**-0.5``; arenas
    (nb, bs, G, D) / (nb, bs, G, Dv) posit patterns (``pcfg`` set) or
    floats; tables (B, W) int32 block tables (sentinel ``nb``); apos
    (B, W*bs) int32 absolute position per virtual slot (``-1`` = dead);
    lens (B,) int32 row frontiers.  Returns (B, G, R, Dv) f32.
    """
    b, g, r, d = q.shape
    nb, bs = k_arena.shape[0], k_arena.shape[1]
    w = tables.shape[1]
    dv = v_arena.shape[-1]
    kidx = _block_index(nb)
    grid_spec = _table_walk_specs(
        tables, apos,
        [
            pl.BlockSpec((1, g, r, d), lambda b, w, tab, ln: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1, bs), lambda b, w, tab, ln: (b, w, 0)),
            pl.BlockSpec((1, bs, g, d), functools.partial(kidx, _nd=4)),
            pl.BlockSpec((1, bs, g, dv), functools.partial(kidx, _nd=4)),
        ],
        (1, g, r, dv),
        [
            pltpu.VMEM((g, r), jnp.float32),        # running max m
            pltpu.VMEM((g, r), jnp.float32),        # denominator l
            pltpu.VMEM((g, r, dv), jnp.float32),    # accumulator
        ])
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, pcfg=pcfg, nw=w, nb=nb,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, g, r, dv), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lens,
      q.astype(jnp.float32), apos.reshape(b, w, bs), k_arena, v_arena)


@functools.partial(jax.jit,
                   static_argnames=("pcfg", "scale", "interpret"))
def paged_decode_attention_mla(q_lat_eff, q_rope, c_arena, r_arena, tables,
                               apos, lens, *,
                               pcfg: Optional[PositConfig] = None,
                               scale: float = 1.0, interpret: bool = True):
    """Fused paged MLA decode: latent-space scores and context straight
    off the block tables.

    q_lat_eff: (B, H, rank) f32 absorbed query; q_rope: (B, H, rope)
    f32; arenas (nb, bs, rank) / (nb, bs, rope); ``scale`` multiplies
    the summed scores (the absorbed-attention convention).  Returns the
    latent context (B, H, rank) f32 — the caller applies ``wuv``.
    """
    b, h, rank = q_lat_eff.shape
    rope = q_rope.shape[-1]
    nb, bs = c_arena.shape[0], c_arena.shape[1]
    w = tables.shape[1]
    kidx = _block_index(nb)
    grid_spec = _table_walk_specs(
        tables, apos,
        [
            pl.BlockSpec((1, h, rank), lambda b, w, tab, ln: (b, 0, 0)),
            pl.BlockSpec((1, h, rope), lambda b, w, tab, ln: (b, 0, 0)),
            pl.BlockSpec((1, 1, bs), lambda b, w, tab, ln: (b, w, 0)),
            pl.BlockSpec((1, bs, rank), functools.partial(kidx, _nd=3)),
            pl.BlockSpec((1, bs, rope), functools.partial(kidx, _nd=3)),
        ],
        (1, h, rank),
        [
            pltpu.VMEM((1, h), jnp.float32),        # running max m
            pltpu.VMEM((1, h), jnp.float32),        # denominator l
            pltpu.VMEM((1, h, rank), jnp.float32),  # latent accumulator
        ])
    return pl.pallas_call(
        functools.partial(_paged_attn_mla_kernel, pcfg=pcfg, nw=w, nb=nb,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, rank), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lens,
      q_lat_eff.astype(jnp.float32), q_rope.astype(jnp.float32),
      apos.reshape(b, w, bs), c_arena, r_arena)


# ---------------------------------------------------------------------------
# Analytic decode-bytes ledger
# ---------------------------------------------------------------------------

_KV_ITEMSIZE = {None: 4, "posit16": 2, "posit8": 1}


def paged_decode_kv_bytes(cfg, table_width: int, block_size: int,
                          kernel: str = "fused") -> int:
    """HBM bytes of KV traffic one decode step moves per batch row,
    summed over layers (the metric ``bench_serve.py`` reports as
    ``decode_kv_B_tok``).

    The fused kernel reads each row's arena blocks ONCE, as stored
    patterns, and everything else lives in VMEM.  The gather path reads
    the arena, writes + reads the gathered virtual-cache copy, and (for
    posit KV) writes + reads the dequantized compute-dtype cache on top
    — the round-trip this kernel deletes.  Scores/probabilities and the
    (B, H, D)-sized q/out tensors are excluded from both sides: they
    are identical traffic and orders of magnitude smaller than KV.
    """
    itemsize = _KV_ITEMSIZE[cfg.kv_posit]
    slots = table_width * block_size
    if cfg.mla:
        kv_elems = slots * (cfg.kv_lora_rank + cfg.qk_rope_dim)
    else:
        kv_elems = slots * cfg.n_kv_heads * 2 * cfg.head_dim
    pattern_bytes = kv_elems * itemsize
    if kernel == "fused":
        per_layer = pattern_bytes                  # one arena read
    elif kernel == "gather":
        # arena read + gathered-copy write + gathered read ...
        per_layer = 3 * pattern_bytes
        if cfg.kv_posit is not None:
            # ... + dequantized compute-dtype cache write + read
            cbytes = 2 if cfg.compute_dtype == "bfloat16" else 4
            per_layer += 2 * kv_elems * cbytes
    else:
        raise ValueError(f"unknown paged decode kernel {kernel!r}")
    return per_layer * cfg.n_layers
