"""Pallas TPU kernel: bit-exact posit matmul through the streaming quire.

``pgemm``: posit patterns (M, K) x posit patterns (K, N) -> posit
patterns (M, N), each output element reduced through the §IV-E quire-lite
accumulator with *exactly one* rounding — the blocked-matmul analogue of
``posit_dot.vpdot_rows``, complementing the dequant+MXU throughput path
in ``posit_gemm`` (which is f32-in/f32-out and rounds per k-tile).

Blocking: grid (M/bm, N/bn, K/bk) with K innermost (sequential); each
step decodes an A tile (bm, bk) and a W tile (bk, bn), forms the
(bm, bk, bn) PIR product lattice on the VPU, column-reduces it over k
into per-(m, n) quire states, and folds those into VMEM scratch via
``core.dot.quire_combine``.  The last K step normalizes + RNE-encodes.

bm * bk * bn bounds the working set (the product lattice), so defaults
keep bm/bn small and bk at the full MAX_DOT_LENGTH tile — this is the
numerics-audit matmul, not the throughput one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import dot as dot_mod
from repro.core.pir import PIR, decode, encode_pir
from repro.core.types import PositConfig

from ._compat import CompilerParams as _CompilerParams

DEFAULT_BLOCKS = (16, dot_mod.MAX_DOT_LENGTH, 16)  # bm, bk, bn


def _read_state(acc_ref, mexp_ref, sticky_ref, nar_ref):
    return dot_mod.QuireState(acc=acc_ref[...], m_exp=mexp_ref[...],
                              sticky=sticky_ref[...],
                              nar=nar_ref[...] != 0)


def _write_state(st, acc_ref, mexp_ref, sticky_ref, nar_ref):
    acc_ref[...] = st.acc
    mexp_ref[...] = st.m_exp
    sticky_ref[...] = st.sticky
    nar_ref[...] = st.nar.astype(jnp.uint32)


def _qgemm_kernel(a_ref, w_ref, o_ref, acc_ref, mexp_ref, sticky_ref,
                  nar_ref, *, cfg: PositConfig, nk: int):
    k = pl.program_id(2)
    a = decode(a_ref[...].astype(jnp.uint32), cfg)        # (bm, bk)
    w = decode(w_ref[...].astype(jnp.uint32), cfg)        # (bk, bn)
    # outer-product lattice (bm, bk, bn) by broadcasting the PIR fields;
    # quire_partial reduces the k axis into per-(m, n) states.
    al = PIR(*(f[:, :, None] for f in a))
    wl = PIR(*(f[None, :, :] for f in w))
    tile = dot_mod.quire_partial(al, wl, axis=1)          # state (bm, bn)

    @pl.when(k == 0)
    def _init():
        _write_state(tile, acc_ref, mexp_ref, sticky_ref, nar_ref)

    @pl.when(k > 0)
    def _accumulate():
        carried = _read_state(acc_ref, mexp_ref, sticky_ref, nar_ref)
        merged = dot_mod.quire_combine(carried, tile)
        _write_state(merged, acc_ref, mexp_ref, sticky_ref, nar_ref)

    @pl.when(k == nk - 1)
    def _finalize():
        state = _read_state(acc_ref, mexp_ref, sticky_ref, nar_ref)
        pir, sticky = dot_mod.quire_finalize(state)
        o_ref[...] = encode_pir(pir, cfg, sticky).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "blocks", "interpret"))
def posit_qgemm(a_patterns, w_patterns, cfg: PositConfig,
                blocks=DEFAULT_BLOCKS, interpret=True):
    """a: posit (M, K); w: posit (K, N) -> posit (M, N), quire-exact."""
    m, k = a_patterns.shape
    k2, n = w_patterns.shape
    if k != k2:
        raise ValueError(
            f"pgemm contraction mismatch: {a_patterns.shape} @ "
            f"{w_patterns.shape}")
    if m == 0 or n == 0 or k == 0:
        # empty contraction -> posit zero (pattern 0); nothing to launch
        return jnp.zeros((m, n), cfg.storage_dtype)
    bm = min(blocks[0], m)
    bk = min(blocks[1], k)
    bn = min(blocks[2], n)
    if bk > dot_mod.MAX_DOT_LENGTH:
        raise ValueError(
            f"pgemm block_k {bk} exceeds MAX_DOT_LENGTH="
            f"{dot_mod.MAX_DOT_LENGTH} (uint32 half-limb column-sum bound)")
    # zero patterns decode to posit zero: padding never perturbs the quire
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    ap = jnp.pad(a_patterns, ((0, pm), (0, pk))) if pm or pk else a_patterns
    wp = jnp.pad(w_patterns, ((0, pk), (0, pn))) if pk or pn else w_patterns
    nk = (k + pk) // bk
    grid = ((m + pm) // bm, (n + pn) // bn, nk)
    out = pl.pallas_call(
        functools.partial(_qgemm_kernel, cfg=cfg, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), cfg.storage_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn, dot_mod._NLIMB), jnp.uint32),  # quire limbs
            pltpu.VMEM((bm, bn), jnp.int32),                   # m_exp
            pltpu.VMEM((bm, bn), jnp.uint32),                  # sticky
            pltpu.VMEM((bm, bn), jnp.uint32),                  # NaR flag
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ap, wp)
    return out[:m, :n]
