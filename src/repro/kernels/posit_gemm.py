"""Pallas TPU kernel: fused posit-dequant matmul (posit weights -> MXU).

The TPU-native analogue of running the paper's conv inner loop on posit
operands (Listing 2): weights stay in posit16/posit8 in HBM (2-4x less
bandwidth), each (bk, bn) tile is decoded to f32 *in VMEM* on the VPU, and
the MXU consumes it immediately.  K is the innermost (sequential) grid
dimension accumulating into the output block.

Blocking: (bm, bk) x (bk, bn) -> (bm, bn), all MXU-aligned multiples of
128 by default; the f32 working set is 3 tiles + the posit tile, sized
well under VMEM (16 MiB/core).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.convert import posit_to_f32
from repro.core.types import PositConfig

from ._compat import CompilerParams as _CompilerParams

DEFAULT_BLOCKS = (256, 256, 256)  # bm, bk, bn


def _gemm_kernel(a_ref, w_ref, o_ref, *, cfg: PositConfig):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = posit_to_f32(w_ref[...].astype(jnp.uint32), cfg)   # VPU decode
    o_ref[...] += jnp.dot(a_ref[...], w,
                          preferred_element_type=jnp.float32)  # MXU


@functools.partial(jax.jit,
                   static_argnames=("cfg", "blocks", "interpret"))
def posit_gemm(a, w_patterns, cfg: PositConfig, blocks=DEFAULT_BLOCKS,
               interpret=True):
    """a: f32 (M, K); w_patterns: posit (K, N) -> f32 (M, N)."""
    m, k = a.shape
    k2, n = w_patterns.shape
    assert k == k2, (a.shape, w_patterns.shape)
    bm = min(blocks[0], m)
    bk = min(blocks[1], k)
    bn = min(blocks[2], n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_gemm_kernel, cfg=cfg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, w_patterns)
