"""Pure-jnp oracles for every kernel in this package.

Each function is the mathematical specification its kernel must match
bit-exactly (tests sweep shapes/dtypes and assert equality / allclose).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.convert import f32_to_posit, posit_to_f32
from repro.core.posit import vpdot
from repro.core.types import PositConfig


def quantize_2d_ref(x, cfg: PositConfig):
    return f32_to_posit(x, cfg)


def dequantize_2d_ref(p, cfg: PositConfig):
    return posit_to_f32(p, cfg)


def posit_gemm_ref(a, w_patterns, cfg: PositConfig):
    w = posit_to_f32(w_patterns, cfg)
    return jnp.dot(a, w, preferred_element_type=jnp.float32)


def vpdot_rows_ref(a_patterns, b_patterns, cfg: PositConfig):
    return vpdot(a_patterns, b_patterns, cfg, axis=-1)
