"""Pure-jnp oracles for every kernel in this package.

Each function is the mathematical specification its kernel must match
bit-exactly (tests sweep shapes/dtypes and assert equality / allclose).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.convert import f32_to_posit, posit_to_f32
from repro.core.posit import vpadd, vpdiv, vpdot, vpmul, vpsub
from repro.core.types import PositConfig


def quantize_2d_ref(x, cfg: PositConfig):
    return f32_to_posit(x, cfg)


def dequantize_2d_ref(p, cfg: PositConfig):
    return posit_to_f32(p, cfg)


def posit_gemm_ref(a, w_patterns, cfg: PositConfig):
    w = posit_to_f32(w_patterns, cfg)
    return jnp.dot(a, w, preferred_element_type=jnp.float32)


def vpdot_rows_ref(a_patterns, b_patterns, cfg: PositConfig):
    """Any length: core.vpdot streams MAX_DOT_LENGTH chunks through
    quire_partial/quire_combine, matching the kernel's K tiling."""
    return vpdot(a_patterns, b_patterns, cfg, axis=-1)


def vpdot_quire_ref(a_patterns, b_patterns, cfg: PositConfig):
    """The exact 512-bit standard-quire reference (order-independent)."""
    return vpdot(a_patterns, b_patterns, cfg, axis=-1, mode="quire")


def pgemm_ref(a_patterns, w_patterns, cfg: PositConfig):
    """Per-output-element quire dot: out[i, j] = vpdot(a[i, :], w[:, j]).

    Materializes the (M, K, N) product lattice — keep shapes small.
    """
    a = jnp.asarray(a_patterns)
    w = jnp.asarray(w_patterns)
    return vpdot(a[:, :, None], w[None, :, :], cfg, axis=1)


def elementwise_ref(a_patterns, b_patterns, cfg: PositConfig, op: str,
                    div_mode: str = "nr3"):
    """Pure-jnp PIR datapath the fused kernel must match bit-exactly."""
    if op == "add":
        return vpadd(a_patterns, b_patterns, cfg)
    if op == "sub":
        return vpsub(a_patterns, b_patterns, cfg)
    if op == "mul":
        return vpmul(a_patterns, b_patterns, cfg)
    if op == "div":
        return vpdiv(a_patterns, b_patterns, cfg, mode=div_mode)
    raise ValueError(f"unknown elementwise op {op!r}")


def elementwise_roundtrip_ref(a_patterns, b_patterns, cfg: PositConfig,
                              op: str):
    """The dequantize -> f32 op -> quantize composition the fused kernel
    replaces.  Double-rounded (f32 RNE then posit RNE), so it can only be
    *less* accurate than the fused single-rounding datapath."""
    fa = posit_to_f32(a_patterns, cfg)
    fb = posit_to_f32(b_patterns, cfg)
    f = {"add": jnp.add, "sub": jnp.subtract,
         "mul": jnp.multiply, "div": jnp.divide}[op]
    return f32_to_posit(f(fa, fb), cfg)
