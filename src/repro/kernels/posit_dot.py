"""Pallas TPU kernel: the faithful PVU vpdot datapath (§IV-E), K-tiled.

One pass of the paper's pipeline per (row, K) tile, entirely in VMEM:
decode -> elementwise significand multiply (16-bit limb partial products)
-> align to the tile max exponent -> 128-bit two's-complement column
accumulation -> and, *across* K tiles, the streaming quire-lite state
(limb columns + alignment exponent + sticky + NaR) carried in VMEM
scratch via ``core.dot.quire_combine``.  The single normalize + RNE
encode happens once, on the last K step — so reductions of any length
round exactly once, and a reduction that fits one tile is bit-identical
to the original monolithic kernel.

This is the numerics-audit kernel (bit-exact posit dot products for
verification tables); the throughput path for large GEMMs is
``posit_gemm`` (dequant + MXU), and the bit-exact posit-in -> posit-out
matmul built on the same streaming quire is ``posit_qgemm``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import dot as dot_mod
from repro.core.pir import decode, encode_pir
from repro.core.types import PositConfig

from ._compat import CompilerParams as _CompilerParams

DEFAULT_ROWS = 128
DEFAULT_BLOCK_K = dot_mod.MAX_DOT_LENGTH


def _read_state(acc_ref, mexp_ref, sticky_ref, nar_ref):
    return dot_mod.QuireState(acc=acc_ref[...],
                              m_exp=mexp_ref[...][:, 0],
                              sticky=sticky_ref[...][:, 0],
                              nar=nar_ref[...][:, 0] != 0)


def _write_state(st, acc_ref, mexp_ref, sticky_ref, nar_ref):
    acc_ref[...] = st.acc
    mexp_ref[...] = st.m_exp[:, None]
    sticky_ref[...] = st.sticky[:, None]
    nar_ref[...] = st.nar.astype(jnp.uint32)[:, None]


def _vpdot_kernel(a_ref, b_ref, o_ref, acc_ref, mexp_ref, sticky_ref,
                  nar_ref, *, cfg: PositConfig, nk: int):
    k = pl.program_id(1)
    a = decode(a_ref[...].astype(jnp.uint32), cfg)
    b = decode(b_ref[...].astype(jnp.uint32), cfg)
    tile = dot_mod.quire_partial(a, b, axis=-1)

    @pl.when(k == 0)
    def _init():
        _write_state(tile, acc_ref, mexp_ref, sticky_ref, nar_ref)

    @pl.when(k > 0)
    def _accumulate():
        carried = _read_state(acc_ref, mexp_ref, sticky_ref, nar_ref)
        merged = dot_mod.quire_combine(carried, tile)
        _write_state(merged, acc_ref, mexp_ref, sticky_ref, nar_ref)

    @pl.when(k == nk - 1)
    def _finalize():
        state = _read_state(acc_ref, mexp_ref, sticky_ref, nar_ref)
        pir, sticky = dot_mod.quire_finalize(state)
        out = encode_pir(pir, cfg, sticky).astype(o_ref.dtype)
        o_ref[...] = out[:, None]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "block_rows", "block_k",
                                    "interpret"))
def vpdot_rows(a_patterns, b_patterns, cfg: PositConfig,
               block_rows: int = DEFAULT_ROWS, block_k: int | None = None,
               interpret=True):
    """Row-wise posit dot product: (R, L) x (R, L) -> (R,) patterns.

    L is unbounded: the reduction runs as a sequential K grid dimension
    of ``block_k`` (default MAX_DOT_LENGTH) tiles whose quire states
    accumulate in VMEM scratch.  L <= block_k is a single tile — the
    exact monolithic §IV-E pipeline.
    """
    r, length = a_patterns.shape
    if a_patterns.shape != b_patterns.shape:
        raise ValueError(
            f"vpdot_rows operand shapes differ: {a_patterns.shape} vs "
            f"{b_patterns.shape}")
    if r == 0 or length == 0:
        # empty quire -> posit zero (pattern 0); nothing to launch
        return jnp.zeros((r,), cfg.storage_dtype)
    bk = min(block_k or DEFAULT_BLOCK_K, length)
    if bk > dot_mod.MAX_DOT_LENGTH:
        raise ValueError(
            f"vpdot_rows block_k {bk} exceeds MAX_DOT_LENGTH="
            f"{dot_mod.MAX_DOT_LENGTH} (uint32 half-limb column-sum bound)")
    pad = (-length) % bk
    if pad:  # zero patterns decode to posit zero: excluded from the quire
        a_patterns = jnp.pad(a_patterns, ((0, 0), (0, pad)))
        b_patterns = jnp.pad(b_patterns, ((0, 0), (0, pad)))
    nk = (length + pad) // bk
    bm = min(block_rows, r)
    grid = (pl.cdiv(r, bm), nk)
    out = pl.pallas_call(
        functools.partial(_vpdot_kernel, cfg=cfg, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), cfg.storage_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, dot_mod._NLIMB), jnp.uint32),   # quire limbs
            pltpu.VMEM((bm, 1), jnp.int32),                 # m_exp
            pltpu.VMEM((bm, 1), jnp.uint32),                # sticky
            pltpu.VMEM((bm, 1), jnp.uint32),                # NaR flag
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a_patterns, b_patterns)
    return out[:, 0]
