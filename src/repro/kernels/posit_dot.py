"""Pallas TPU kernel: the faithful PVU vpdot datapath (§IV-E).

One pass of the paper's pipeline per row block, entirely in VMEM:
decode -> elementwise significand multiply (16-bit limb partial products)
-> align to the row max exponent -> 128-bit two's-complement column
accumulation -> single normalize + RNE encode.

This is the numerics-audit kernel (bit-exact posit dot products for
verification tables); the throughput path for large GEMMs is
``posit_gemm`` (dequant + MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import dot as dot_mod
from repro.core.pir import decode, encode_pir
from repro.core.types import PositConfig

DEFAULT_ROWS = 128


def _vpdot_kernel(a_ref, b_ref, o_ref, *, cfg: PositConfig):
    a = decode(a_ref[...].astype(jnp.uint32), cfg)
    b = decode(b_ref[...].astype(jnp.uint32), cfg)
    pir, sticky = dot_mod.vpdot(a, b, cfg, axis=-1)
    out = encode_pir(pir, cfg, sticky).astype(o_ref.dtype)
    o_ref[...] = out[:, None]


@functools.partial(jax.jit,
                   static_argnames=("cfg", "block_rows", "interpret"))
def vpdot_rows(a_patterns, b_patterns, cfg: PositConfig,
               block_rows: int = DEFAULT_ROWS, interpret=True):
    """Row-wise posit dot product: (R, L) x (R, L) -> (R,) patterns."""
    r, length = a_patterns.shape
    assert a_patterns.shape == b_patterns.shape
    assert length <= dot_mod.MAX_DOT_LENGTH
    bm = min(block_rows, r)
    grid = (pl.cdiv(r, bm),)
    out = pl.pallas_call(
        functools.partial(_vpdot_kernel, cfg=cfg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, length), lambda i: (i, 0)),
            pl.BlockSpec((bm, length), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), cfg.storage_dtype),
        interpret=interpret,
    )(a_patterns, b_patterns)
    return out[:, 0]
