"""Pallas TPU kernel: fused elementwise PVU ops (vadd/vsub/vmul/vdiv).

The paper's headline datapath (§IV-B/C/D) is the *vector* add/sub/mul/div
unit; this kernel runs one pass of that pipeline per VMEM tile, entirely
in the posit domain:

    decode (Logic 1) -> PIR arithmetic (core.arith) -> single-RNE encode

No f32 round-trip anywhere: inputs and outputs are posit bit patterns
(uint8/uint16/uint32 per ``cfg.storage_dtype``), so results are exactly
rounded once — the fused kernel is never *less* accurate than the
``dequantize -> f32 op -> quantize`` composition, and for add/sub/mul
(and ``div mode='exact'``) it is correctly rounded by construction.

Shares the decode/encode helpers of ``posit_codec``/``posit_dot``
(``repro.core.pir``) so there is one datapath, not three.  Division
supports both the paper's 3-iteration Newton-Raphson (``mode='nr3'``,
~95.8 % exact-match) and the beyond-paper exactly-rounded restoring
divider (``mode='exact'``).

Target: TPU via pl.pallas_call (VPU elementwise, 8x128 lanes);
``interpret=True`` validates on CPU against ``core.softposit_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import arith
from repro.core.pir import decode, encode_pir
from repro.core.types import PositConfig

# VPU-aligned default tile, matching the codec kernel: the PIR working set
# is ~6 u32 planes per operand, so (256, 512) stays well under VMEM.
DEFAULT_BLOCK = (256, 512)

OPS = ("add", "sub", "mul", "div")
DIV_MODES = ("nr3", "exact")


def _ew_kernel(a_ref, b_ref, o_ref, *, cfg: PositConfig, op: str,
               div_mode: str):
    a = decode(a_ref[...].astype(jnp.uint32), cfg)
    b = decode(b_ref[...].astype(jnp.uint32), cfg)
    if op == "add":
        pir, sticky = arith.vpadd(a, b, cfg)
    elif op == "sub":
        pir, sticky = arith.vpsub(a, b, cfg)
    elif op == "mul":
        pir, sticky = arith.vpmul(a, b, cfg)
    elif op == "div":
        pir, sticky = arith.vpdiv(a, b, cfg, mode=div_mode)
    else:
        raise ValueError(f"unknown elementwise op {op!r}")
    o_ref[...] = encode_pir(pir, cfg, sticky).astype(o_ref.dtype)


def _grid(shape, block):
    bm = min(block[0], shape[0])
    bn = min(block[1], shape[1])
    return (pl.cdiv(shape[0], bm), pl.cdiv(shape[1], bn)), (bm, bn)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "op", "div_mode", "block",
                                    "interpret"))
def elementwise_2d(a, b, cfg: PositConfig, op: str, div_mode: str = "nr3",
                   block=DEFAULT_BLOCK, interpret=True):
    """Fused posit elementwise op on (M, N) pattern arrays.

    a, b : posit patterns in ``cfg.storage_dtype``; same shape.
    op   : one of ``OPS``; ``div_mode`` selects the divider datapath.
    """
    assert a.shape == b.shape, (a.shape, b.shape)
    assert op in OPS, op
    assert div_mode in DIV_MODES, div_mode
    grid, (bm, bn) = _grid(a.shape, block)
    return pl.pallas_call(
        functools.partial(_ew_kernel, cfg=cfg, op=op, div_mode=div_mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(a.shape, cfg.storage_dtype),
        interpret=interpret,
    )(a, b)
