"""Version shims shared by the Pallas kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams (0.4.x -> 0.5+)
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
