from .config import ModelConfig
from .registry import build, get_family

__all__ = ["ModelConfig", "build", "get_family"]
