"""Hymba — hybrid-head LM: parallel attention + Mamba2-style SSM heads.

Per layer, the *same* input feeds (a) GQA attention heads (sliding-window
in most layers, full/global in ``cfg.global_layers``) and (b) SSM heads
(scalar-per-head data-dependent decay, state size N=16); the two outputs
are RMS-normalized and averaged before the output projection
(arXiv:2411.13676).  ``cfg.n_meta_tokens`` learnable meta tokens are
prepended at train/prefill time.

SSD engine: chunk-parallel with scalar per-head log decays ((C, C) ratio
matrices only — no channel dimension, so exponents stay <= 0 and memory
stays tiny).  A step form drives decode.

Deviation noted in DESIGN.md: the short causal conv1d in front of the SSM
branch is omitted (state bookkeeping only, no roofline impact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .config import ModelConfig


def init_params(key, cfg: ModelConfig):
    d = cfg.d_model
    hs, p_dim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_in = hs * p_dim

    def init_layer(k):
        ks = jax.random.split(k, 10)
        s = d ** -0.5
        return {
            "ln1": L.init_rms_norm(d, cfg),
            "ln2": L.init_rms_norm(d, cfg),
            # attention branch
            "wq": L.init_dense(ks[0], d, cfg.n_heads * cfg.head_dim),
            "wk": L.init_dense(ks[1], d, cfg.n_kv_heads * cfg.head_dim),
            "wv": L.init_dense(ks[2], d, cfg.n_kv_heads * cfg.head_dim),
            "attn_norm": L.init_rms_norm(cfg.n_heads * cfg.head_dim, cfg),
            # ssm branch
            "in_proj": L.init_dense(ks[3], d, 2 * d_in + 2 * n + hs),
            "A_log": jnp.zeros((hs,), jnp.float32),
            "dt_bias": jnp.zeros((hs,), jnp.float32),
            "D": jnp.ones((hs,), jnp.float32),
            "ssm_norm": L.init_rms_norm(d_in, cfg),
            # merge + mlp
            "wo": L.init_dense(ks[4], d_in, d),
            "mlp": L.init_mlp(ks[5], cfg),
        }

    keys = jax.random.split(key, 5)
    layer_keys = jax.random.split(keys[0], cfg.n_layers)
    params = {
        "tok_embed": jax.random.normal(
            keys[1], (cfg.vocab, d), jnp.float32) * 0.02,
        "layers": jax.vmap(init_layer)(layer_keys),
        "final_norm": L.init_rms_norm(d, cfg),
        "lm_head": L.init_dense(keys[2], d, cfg.vocab),
    }
    if cfg.n_meta_tokens:
        params["meta_tokens"] = jax.random.normal(
            keys[3], (cfg.n_meta_tokens, d), jnp.float32) * 0.02
    return params


# ---------------------------------------------------------------------------
# SSD (scalar-decay chunked scan)
# ---------------------------------------------------------------------------

def ssd_chunked(x, b_in, c_in, dt, a_log, h0, chunk: int):
    """x: (B,S,H,P); b_in,c_in: (B,S,N); dt: (B,S,H) (post-softplus);
    h0: (B,H,P,N).  Returns (y (B,S,H,P), h_final)."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    la = (-jnp.exp(a_log))[None, None, :] * dt           # log decay <= 0

    xs = x.reshape(bsz, nc, chunk, h, p).transpose(1, 0, 3, 2, 4)
    bs = b_in.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cs = c_in.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    dts = dt.reshape(bsz, nc, chunk, h).transpose(1, 0, 3, 2)
    las = la.reshape(bsz, nc, chunk, h).transpose(1, 0, 3, 2)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))       # j <= c inclusive

    def per_chunk(hprev, inp):
        xx, bb, cc, dd, ll = inp    # (B,H,C,P) (B,C,N) (B,C,N) (B,H,C) (B,H,C)
        li = jnp.cumsum(ll, axis=-1)                     # (B,H,C) inclusive
        diff = li[:, :, :, None] - li[:, :, None, :]     # (B,H,C,C)
        ratio = jnp.where(tri[None, None], jnp.exp(jnp.minimum(diff, 0.0)),
                          0.0)
        sc = jnp.einsum("bcn,bjn->bcj", cc, bb)          # (B,C,C)
        scores = sc[:, None] * ratio * dd[:, :, None, :]  # (B,H,C,C)
        y = jnp.einsum("bhcj,bhjp->bhcp", scores, xx)
        # inter-chunk: y += exp(li) * C . h_prev
        y += jnp.einsum("bcn,bhpn->bhcp", cc, hprev) * \
            jnp.exp(li)[..., None]
        # state update
        l_tot = li[:, :, -1:]
        wsc = jnp.exp(l_tot - li) * dd                   # (B,H,C)
        upd = jnp.einsum("bhc,bhcp,bcn->bhpn", wsc, xx, bb)
        hnew = jnp.exp(l_tot[:, :, 0])[..., None, None] * hprev + upd
        return hnew, y

    hfin, ys = lax.scan(per_chunk, h0, (xs, bs, cs, dts, las))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(bsz, s, h, p)
    return y, hfin


def ssd_step(x, b_in, c_in, dt, a_log, h):
    """Single decode step.  x: (B,H,P); b_in,c_in: (B,N); dt: (B,H)."""
    a = jnp.exp((-jnp.exp(a_log))[None, :] * dt)         # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x, b_in)
    h = a[..., None, None] * h + upd
    y = jnp.einsum("bhpn,bn->bhp", h, c_in)
    return y, h


# ---------------------------------------------------------------------------
# hybrid block
# ---------------------------------------------------------------------------

def _split_ssm_proj(p, x, cfg: ModelConfig):
    hs, p_dim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_in = hs * p_dim
    z = L.dense(p["in_proj"], x, cfg)
    xs, gate, b_in, c_in, dt = jnp.split(
        z, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])
    return xs, gate, b_in.astype(jnp.float32), c_in.astype(jnp.float32), dt


def _ssm_branch_full(p, x, cfg: ModelConfig, h0=None):
    bsz, s, _ = x.shape
    hs, p_dim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xs, gate, b_in, c_in, dt = _split_ssm_proj(p, x, cfg)
    xh = xs.reshape(bsz, s, hs, p_dim).astype(jnp.float32)
    xh = xh.transpose(0, 2, 1, 3).transpose(0, 2, 1, 3)  # no-op, clarity
    if h0 is None:
        h0 = jnp.zeros((bsz, hs, p_dim, n), jnp.float32)
    chunk = min(cfg.wkv_chunk, s)
    y, hfin = ssd_chunked(xh, b_in, c_in, dt, p["A_log"], h0, chunk)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(bsz, s, hs * p_dim).astype(x.dtype)
    y = y * jax.nn.silu(gate)
    return L.rms_norm(p["ssm_norm"], y, cfg), hfin


def _attn_branch_full(p, x, positions, cfg: ModelConfig, *, is_global):
    bsz, s, _ = x.shape
    q = L.dense(p["wq"], x, cfg).reshape(bsz, s, cfg.n_heads, cfg.head_dim)
    k = L.dense(p["wk"], x, cfg).reshape(bsz, s, cfg.n_kv_heads, cfg.head_dim)
    v = L.dense(p["wv"], x, cfg).reshape(bsz, s, cfg.n_kv_heads, cfg.head_dim)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    window = 0 if is_global else cfg.sliding_window
    out = L.flash_attention(q, k, v, causal=True, cfg=cfg, window=window)
    out = out.reshape(bsz, s, cfg.n_heads * cfg.head_dim)
    return L.rms_norm(p["attn_norm"], out, cfg), (k, v)


def _merge(p, attn_out, ssm_out, cfg: ModelConfig):
    return L.dense(p["wo"], 0.5 * (attn_out + ssm_out), cfg)


def _forward(params, tokens, cfg: ModelConfig):
    bsz, s0 = tokens.shape
    x = params["tok_embed"][tokens].astype(L.cdtype(cfg))
    if cfg.n_meta_tokens:
        meta = jnp.broadcast_to(
            params["meta_tokens"][None].astype(x.dtype),
            (bsz, cfg.n_meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta, x[:, : s0 - cfg.n_meta_tokens]], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]

    def body(h, lp, *, is_global):
        xin = L.rms_norm(lp["ln1"], h, cfg)
        a, _ = _attn_branch_full(lp, xin, positions, cfg,
                                 is_global=is_global)
        m, _ = _ssm_branch_full(lp, xin, cfg)
        h = h + _merge(lp, a, m, cfg)
        hh = L.rms_norm(lp["ln2"], h, cfg)
        return h + L.mlp(lp["mlp"], hh, cfg)

    # the SWA/global split is static, so scan the contiguous SWA runs and
    # unroll only the (few) global layers: SWA attention FLOPs stay
    # windowed in the lowered HLO, global layers pay full O(S^2).
    _swa = functools.partial(body, is_global=False)

    def swa_body(h, lp):
        return _swa(h, lp), None

    if cfg.remat == "layer":
        swa_body = jax.checkpoint(swa_body)

    bounds = sorted(set(cfg.global_layers))
    start = 0
    for g in bounds + [cfg.n_layers]:
        if g > start:   # scan the SWA run [start, g)
            run = jax.tree.map(lambda t: t[start:g], params["layers"])
            x, _ = lax.scan(swa_body, x, run)
        if g < cfg.n_layers:
            lp = jax.tree.map(lambda t: t[g], params["layers"])
            x = body(x, lp, is_global=True)
        start = g + 1
    return L.rms_norm(params["final_norm"], x, cfg)


def train_loss(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    x = _forward(params, tokens, cfg)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((bsz, 1), tokens.dtype)], axis=1)
    mask = jnp.ones((bsz, s), jnp.float32).at[:, -1].set(0.0)
    if cfg.n_meta_tokens:
        mask = mask.at[:, : cfg.n_meta_tokens].set(0.0)
    w = params["lm_head"]["w"].astype(x.dtype)
    ck = min(cfg.loss_chunk, s)

    def chunk_loss(ci):
        xs = lax.dynamic_slice_in_dim(x, ci * ck, ck, 1)
        ls = lax.dynamic_slice_in_dim(labels, ci * ck, ck, 1)
        ms = lax.dynamic_slice_in_dim(mask, ci * ck, ck, 1)
        logits = (xs @ w).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], -1)[..., 0]
        return ((logz - gold) * ms).sum(), ms.sum()

    losses, counts = lax.map(chunk_loss, jnp.arange(s // ck))
    return losses.sum() / jnp.maximum(counts.sum(), 1.0)


def logits_fn(params, tokens, cfg: ModelConfig, visual=None):
    x = _forward(params, tokens, cfg)
    return (x @ params["lm_head"]["w"].astype(x.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# serving: ring SWA caches + tiny SSM state (+ full cache on global layers)
# ---------------------------------------------------------------------------

def _cache_dtype(cfg: ModelConfig):
    if cfg.kv_posit:
        return L.pcfg(cfg.kv_posit).storage_dtype
    return L.cdtype(cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    hs, p_dim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    w = cfg.sliding_window or max_len
    t_swa = min(max_len, w)
    kv = (batch, t_swa, cfg.n_kv_heads, cfg.head_dim)
    kv_g = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    dt = _cache_dtype(cfg)
    return {
        # ring caches for every layer; full-length caches only for the
        # (few) global layers, stacked separately to bound memory
        "k_swa": jnp.zeros((cfg.n_layers, *kv), dt),
        "v_swa": jnp.zeros((cfg.n_layers, *kv), dt),
        "k_glb": jnp.zeros((len(cfg.global_layers), *kv_g), dt),
        "v_glb": jnp.zeros((len(cfg.global_layers), *kv_g), dt),
        "ssm": jnp.zeros((cfg.n_layers, batch, hs, p_dim, n), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
        "lens": jnp.zeros((batch,), jnp.int32),
        "max_len": jnp.asarray(max_len, jnp.int32),
    }


def decode_step(params, cache, token, cfg: ModelConfig, active=None):
    """``active``: optional (B,) bool scheduler mask — inactive rows'
    ``lens`` stay put (see ``transformer.decode_step``)."""
    from repro.core.convert import f32_to_posit
    pos = cache["len"]
    bsz = token.shape[0]
    if cfg.global_layers:
        L.check_cache_capacity(pos, cache["k_glb"].shape[2],
                               "global-layer KV cache")
    x = params["tok_embed"][token][:, None, :].astype(L.cdtype(cfg))
    is_global = [i in cfg.global_layers for i in range(cfg.n_layers)]
    glb_index = {i: j for j, i in enumerate(cfg.global_layers)}

    def quant(t):
        if cfg.kv_posit:
            return f32_to_posit(t.astype(jnp.float32), L.pcfg(cfg.kv_posit))
        return t.astype(L.cdtype(cfg))

    # unrolled python loop over layers: global/SWA layout differs per
    # layer, and n_layers is static (32)
    k_swa, v_swa = cache["k_swa"], cache["v_swa"]
    k_glb, v_glb = cache["k_glb"], cache["v_glb"]
    ssm = cache["ssm"]
    h = x
    layers = params["layers"]
    for li in range(cfg.n_layers):
        lp = jax.tree.map(lambda t: t[li], layers)
        xin = L.rms_norm(lp["ln1"], h, cfg)
        q = L.dense(lp["wq"], xin, cfg).reshape(
            bsz, 1, cfg.n_heads, cfg.head_dim)
        k = L.dense(lp["wk"], xin, cfg).reshape(
            bsz, 1, cfg.n_kv_heads, cfg.head_dim)
        v = L.dense(lp["wv"], xin, cfg).reshape(
            bsz, 1, cfg.n_kv_heads, cfg.head_dim)
        q = L.apply_rope(q, pos[None, None], cfg.rope_theta)
        k = L.apply_rope(k, pos[None, None], cfg.rope_theta)
        if is_global[li]:
            gi = glb_index[li]
            kc = L.guarded_cache_update(k_glb[gi], quant(k), pos, 1)
            vc = L.guarded_cache_update(v_glb[gi], quant(v), pos, 1)
            k_glb = k_glb.at[gi].set(kc)
            v_glb = v_glb.at[gi].set(vc)
            att = L.decode_attention(q, kc, vc, pos + 1, cfg=cfg,
                                     kv_posit=cfg.kv_posit)
        else:
            # ring buffer: write at pos % window, rotation-aware masking
            t_swa = k_swa.shape[2]
            slot = lax.rem(pos, t_swa)
            kc = L.guarded_cache_update(k_swa[li], quant(k), slot, 1)
            vc = L.guarded_cache_update(v_swa[li], quant(v), slot, 1)
            k_swa = k_swa.at[li].set(kc)
            v_swa = v_swa.at[li].set(vc)
            att = L.decode_attention(q, kc, vc, pos + 1, cfg=cfg,
                                     kv_posit=cfg.kv_posit, ring=True)
        att = att.reshape(bsz, 1, cfg.n_heads * cfg.head_dim)
        att = L.rms_norm(lp["attn_norm"], att, cfg)

        xs, gate, b_in, c_in, dt = _split_ssm_proj(lp, xin, cfg)
        xh = xs[:, 0].reshape(bsz, cfg.ssm_heads,
                              cfg.ssm_head_dim).astype(jnp.float32)
        y, hnew = ssd_step(xh, b_in[:, 0], c_in[:, 0], dt[:, 0],
                           lp["A_log"], ssm[li])
        ssm = ssm.at[li].set(hnew)
        y = y + lp["D"][None, :, None] * xh
        y = y.reshape(bsz, 1, -1).astype(h.dtype) * jax.nn.silu(gate)
        y = L.rms_norm(lp["ssm_norm"], y, cfg)

        h = h + _merge(lp, att, y, cfg)
        hh = L.rms_norm(lp["ln2"], h, cfg)
        h = h + L.mlp(lp["mlp"], hh, cfg)

    h = L.rms_norm(params["final_norm"], h, cfg)
    logits = (h[:, 0, :] @ params["lm_head"]["w"].astype(h.dtype))
    new_cache = dict(cache, k_swa=k_swa, v_swa=v_swa, k_glb=k_glb,
                     v_glb=v_glb, ssm=ssm, len=pos + 1)
    if "lens" in cache:
        adv = jnp.ones((bsz,), jnp.int32) if active is None \
            else jnp.asarray(active).astype(jnp.int32)
        new_cache["lens"] = cache["lens"] + adv
    return logits.astype(jnp.float32), new_cache


def prefill(params, tokens, cfg: ModelConfig, visual=None, *,
            max_len=None):
    """Simple prefill: run decode_step over the prompt (hybrid caches have
    heterogeneous layouts; throughput prefill would fuse, serving tests
    only need correctness).  ``max_len`` preallocates decode headroom."""
    bsz, s = tokens.shape
    ml = max(s + 1, cfg.sliding_window or s + 1) if max_len is None \
        else int(max_len)
    if ml < s:
        raise ValueError(f"prefill max_len={ml} < prompt length {s}")
    cache = init_cache(cfg, bsz, ml)

    def step(cache, tok):
        logits, cache = decode_step(params, cache, tok, cfg)
        return cache, logits

    cache, logits = lax.scan(step, cache, tokens.T)
    return cache, logits[-1]
