"""Model configuration (one dataclass covers every assigned architecture).

Each assigned arch instantiates this with its exact published dimensions
(see ``repro/configs/``); smoke tests use ``reduced()`` copies.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "transformer"   # transformer | rwkv6 | hymba | whisper
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    act: str = "silu"             # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # gemma-style details
    scale_embed: bool = False     # multiply embeddings by sqrt(d_model)
    norm_plus_one: bool = False   # RMSNorm weight stored as (1 + w)

    # --- MoE ---
    n_experts: int = 0            # 0 = dense
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25

    # --- MLA (minicpm3) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- hybrid / SSM (rwkv6, hymba) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    sliding_window: int = 0       # 0 = full attention
    global_layers: Tuple[int, ...] = ()   # hymba: full-attn layer ids
    n_meta_tokens: int = 0
    wkv_chunk: int = 64
    decay_lora: int = 64          # rwkv6 data-dependent decay lora rank

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0          # frames after the conv stub

    # --- multimodal stub ---
    n_visual_tokens: int = 0      # internvl: patch embeds prepended

    # --- posit integration (the paper's technique) ---
    weight_posit: Optional[str] = None    # None | 'posit16' | 'posit8'
    kv_posit: Optional[str] = None
    paged_attn_kernel: str = "gather"     # paged decode: 'gather' (jnp
                                          # reference) | 'fused' (Pallas
                                          # block-table walk, posit
                                          # decode in-kernel)
    grad_compress: Optional[str] = None   # cross-pod gradient posit
    posit_exact_linear: bool = False      # dense() via quire-exact pgemm
                                          # (numerics audits; slow)

    # --- distribution / memory policy ---
    compute_dtype: str = "float32"        # activations: float32 | bfloat16
    seq_shard_activations: bool = False   # Megatron-SP style constraint
    fsdp: bool = False                    # shard params/opt over 'data' too
    batch_axes: Tuple[str, ...] = ("data",)   # mesh axes carrying batch
    remat: str = "layer"                  # none | layer
    causal_skip: str = "mask"             # mask | cond (skip future blocks)
    grad_accum: int = 1                   # microbatches per train step
    loss_chunk: int = 2048                # vocab-loss sequence chunking
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def q_dim(self) -> int:
        if self.mla:
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16, d_ff=128, vocab=256,
            loss_chunk=64, attn_chunk_q=16, attn_chunk_kv=32, wkv_chunk=8,
        )
        if self.is_moe:
            small.update(n_experts=4, top_k=2, d_ff_expert=32)
        if self.mla:
            small.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                         qk_rope_dim=8, v_head_dim=16, head_dim=16)
        if self.family == "rwkv6":
            small.update(n_heads=4, head_dim=16, decay_lora=8)
        if self.family == "hymba":
            small.update(ssm_state=4, ssm_heads=4, ssm_head_dim=16,
                         sliding_window=16, global_layers=(0,),
                         n_meta_tokens=4)
        if self.family == "whisper":
            small.update(encoder_layers=2, encoder_seq=32)
        if self.n_visual_tokens:
            small.update(n_visual_tokens=8)
        small.update(overrides)
        return dataclasses.replace(self, **small)
