"""Model family registry: a uniform protocol over the four families."""
from __future__ import annotations

from types import SimpleNamespace

from . import hymba, rwkv6, transformer, whisper
from .config import ModelConfig

_FAMILIES = {
    "transformer": transformer,
    "rwkv6": rwkv6,
    "hymba": hymba,
    "whisper": whisper,
}


def get_family(cfg: ModelConfig):
    """Returns the module implementing the model protocol for ``cfg``."""
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}") from None


def build(cfg: ModelConfig):
    """Bundle the protocol functions with the config (convenience)."""
    fam = get_family(cfg)
    return SimpleNamespace(
        cfg=cfg,
        init_params=lambda key: fam.init_params(key, cfg),
        train_loss=lambda params, batch: fam.train_loss(params, batch, cfg),
        logits=lambda params, tokens, **kw: fam.logits_fn(
            params, tokens, cfg, **kw),
        init_cache=lambda batch, max_len: fam.init_cache(cfg, batch, max_len),
        prefill=lambda params, tokens, **kw: fam.prefill(
            params, tokens, cfg, **kw),
        decode_step=lambda params, cache, token: fam.decode_step(
            params, cache, token, cfg),
    )
