"""Shared neural layers (pure functional, pjit/GSPMD-friendly).

Conventions
-----------
* params are plain dict pytrees of jnp arrays; init functions take an rng
  key and a ModelConfig and are ``jax.eval_shape``-safe (used by the
  dry-run to build ShapeDtypeStruct trees without allocating).
* activations run in ``cfg.compute_dtype``; params stay float32 unless a
  serving transform quantized them to posit patterns (unsigned dtypes), in
  which case every consumer dequantizes on the fly (the paper's technique
  as a storage dtype).
* attention is chunked-flash (online softmax over KV blocks) in pure JAX
  so the same code lowers on TPU *and* CPU; the causal variant can skip
  future blocks with lax.cond (cfg.causal_skip='cond').
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.convert import posit_to_f32
from repro.core.types import POSIT8, POSIT16
from .config import ModelConfig

_PCFGS = {"posit16": POSIT16, "posit8": POSIT8}


def pcfg(name: str):
    return _PCFGS[name]


def cdtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def maybe_dequant(w, cfg: ModelConfig):
    """Posit-quantized weights (unsigned ints) decode on the fly."""
    if jnp.issubdtype(w.dtype, jnp.unsignedinteger):
        return posit_to_f32(w, pcfg(cfg.weight_posit or "posit16"))
    return w


def dense(p, x, cfg: ModelConfig):
    if cfg.posit_exact_linear:
        return dense_posit_exact(p, x, cfg)
    w = maybe_dequant(p["w"], cfg).astype(x.dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def dense_posit_exact(p, x, cfg: ModelConfig, interpret: bool = True):
    """Bit-exact posit linear for numerics audits (cfg.posit_exact_linear).

    Runs the paper's §IV-E datapath end to end in the posit domain:
    activations quantize once, ``kernels.ops.pgemm`` reduces every output
    element through the streaming quire (one rounding each), the bias
    adds with the fused single-rounding ``vadd``, and the result
    dequantizes once.  Exactly three roundings per output regardless of
    K — the float path rounds per f32 op — so this is the ground truth
    the throughput ``dense`` is audited against.  Orders of magnitude
    slower; never use it on a serving path.
    """
    from repro.kernels import ops as kops   # keep pallas out of model import
    pc = pcfg(cfg.weight_posit or "posit16")
    w = p["w"]
    wq = (w if jnp.issubdtype(w.dtype, jnp.unsignedinteger)
          else kops.quantize(w.astype(jnp.float32), pc, interpret=interpret))
    xq = kops.quantize(x.astype(jnp.float32), pc, interpret=interpret)
    yq = kops.pgemm(xq, wq, pc, interpret=interpret)
    if "b" in p:
        bq = kops.quantize(p["b"].astype(jnp.float32), pc,
                           interpret=interpret)
        yq = kops.vadd(yq, bq, pc, interpret=interpret)
    return posit_to_f32(yq, pc).astype(x.dtype)


def init_dense(key, d_in, d_out, bias=False, scale=None):
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def rms_norm(p, x, cfg: ModelConfig):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + cfg.norm_eps)
    w = p["scale"].astype(jnp.float32)
    if cfg.norm_plus_one:
        w = 1.0 + w
    return (x * w).astype(dt)


def init_rms_norm(d, cfg: ModelConfig):
    init = jnp.zeros if cfg.norm_plus_one else jnp.ones
    return {"scale": init((d,), jnp.float32)}


def layer_norm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


def init_layer_norm(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked flash attention (pure JAX online softmax)
# ---------------------------------------------------------------------------

_NEG = -1e30


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (static shapes only)."""
    c = min(target, n)
    while n % c:
        c -= 1
    return c


def _attn_block(q, k, v, bias):
    """q: (B,G,R,Qc,D) k: (B,G,Kc,D) v: (B,G,Kc,Dv) bias: (Qc,Kc) or None."""
    s = jnp.einsum("bgrqd,bgkd->bgrqk", q, k).astype(jnp.float32)
    if bias is not None:
        s = s + bias
    return s


def _attn_context_parallel(q, k, v, cfg: ModelConfig):
    """Context-parallel attention sharding: q's sequence dim over 'model',
    k/v replicated over 'model'.

    Rationale (§Perf iteration 1): when head counts do not divide TP=16
    (kv=8, H=14/24/25/40...), GSPMD falls back to sharding the *head_dim
    contraction* of the score einsum, inserting an all-reduce of every
    (qc, kc) score block — 13 TB/chip on granite-moe prefill.  The
    sequence dim always divides, keeps the contraction local, and
    composes with the Megatron-SP residual constraint (same layout, no
    resharding between layers).  No-op outside a mesh context.
    """
    if not cfg.seq_shard_activations:
        return q, k, v
    try:
        from jax.sharding import PartitionSpec as P
        baxes = tuple(cfg.batch_axes)
        q = lax.with_sharding_constraint(q, P(baxes, "model", None, None))
        k = lax.with_sharding_constraint(k, P(baxes, None, None, None))
        v = lax.with_sharding_constraint(v, P(baxes, None, None, None))
    except (ValueError, RuntimeError, TypeError, NameError):
        pass
    return q, k, v


def flash_attention(q, k, v, *, causal: bool, cfg: ModelConfig,
                    window: int = 0, q_offset: int = 0, kv_mask=None,
                    q_positions=None):
    """q: (B,S,H,D); k,v: (B,T,G,D[v]) grouped-query; returns (B,S,H,Dv).

    Scans KV in blocks with an online-softmax carry; the causal variant
    optionally skips strictly-future blocks with lax.cond.

    ``kv_mask``: optional (B, T) bool — False keys are excluded for that
    batch row (left-padded ragged prompts in the serving engine, padded
    or garbage cache slots in the chunked-prefill lane).

    ``q_positions``: optional (B, S) int32 absolute position per query
    (chunked prefill: every batch row sits at its own cache frontier);
    overrides the shared ``q_offset + arange`` positions, making the
    causal/window bias per-row.

    KV is always padded up to a multiple of ``cfg.attn_chunk_kv``, so
    KV block ``i`` covers absolute positions ``[i*kc, (i+1)*kc)`` no
    matter the total KV length: a full-prompt prefill and a chunked
    prefill reading back the same positions reduce in bitwise-identical
    groups (the scheduler's chunked-mode identity guarantee).  Padded
    keys are excluded via ``kv_mask``; once the running max is finite a
    fully-masked block is an exact no-op (``exp`` underflows to 0), and
    leading fully-masked blocks are annihilated exactly by the first
    valid block's ``alpha = exp(-1e30 - m) == 0`` rescale.
    """
    q, k, v = _attn_context_parallel(q, k, v, cfg)
    b, s_len, h, d = q.shape
    t_len, g = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    r = h // g
    scale = d ** -0.5
    qc = _pick_chunk(s_len, cfg.attn_chunk_q)
    kc = int(cfg.attn_chunk_kv)
    t_pad = -(-t_len // kc) * kc
    if t_pad != t_len:
        pad = t_pad - t_len
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_mask is None:
            kv_mask = jnp.ones((b, t_len), bool)
        kv_mask = jnp.pad(kv_mask, ((0, 0), (0, pad)))
    n_q, n_k = s_len // qc, t_pad // kc

    qg = (q.reshape(b, n_q, qc, g, r, d).transpose(1, 0, 3, 4, 2, 5)
          * scale)                                          # (nq,B,G,R,qc,D)
    kg = k.reshape(b, n_k, kc, g, d).transpose(1, 0, 3, 2, 4)
    vg = v.reshape(b, n_k, kc, g, dv).transpose(1, 0, 3, 2, 4)
    km = (kv_mask.reshape(b, n_k, kc).transpose(1, 0, 2)
          if kv_mask is not None else None)                 # (nk,B,kc)

    if q_positions is not None:
        q_pos = jnp.asarray(q_positions, jnp.int32) \
            .reshape(b, n_q, qc).transpose(1, 0, 2)         # (nq,B,qc)
    else:
        q_pos = q_offset + jnp.arange(s_len).reshape(n_q, qc)
    k_pos = jnp.arange(t_pad).reshape(n_k, kc)

    def one_q_chunk(qi):
        qblk = qg[qi]
        qp = q_pos[qi]                                      # (qc,) | (B,qc)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kp = kg[ki], vg[ki], k_pos[ki]

            def compute(args):
                m, l, acc = args
                # qp[..., :, None] - kp broadcasts to (qc,kc) for shared
                # positions or (B,qc,kc) for per-row positions
                bias = jnp.zeros((qc, kc), jnp.float32)
                if causal:
                    bias = jnp.where(
                        qp[..., :, None] >= kp, 0.0, _NEG)
                if window:
                    bias = bias + jnp.where(
                        qp[..., :, None] - kp < window, 0.0, _NEG)
                if bias.ndim == 3:                          # per-row bias
                    bias = bias[:, None, None]              # (B,1,1,qc,kc)
                sblk = _attn_block(qblk, kblk, vblk, bias)  # (B,G,R,qc,kc)
                if km is not None:
                    sblk = jnp.where(
                        km[ki][:, None, None, None, :], sblk, _NEG)
                m_new = jnp.maximum(m, sblk.max(-1))
                p = jnp.exp(sblk - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + p.sum(-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bgrqk,bgkv->bgrqv", p.astype(vblk.dtype), vblk
                ).astype(jnp.float32)
                return m_new, l_new, acc_new

            if causal and cfg.causal_skip == "cond":
                relevant = kp[0] <= qp.max()
                if window:
                    relevant &= (qp.min() - kp[-1]) < window
                m, l, acc = lax.cond(relevant, compute,
                                     lambda a: a, (m, l, acc))
            else:
                m, l, acc = compute((m, l, acc))
            return (m, l, acc), None

        m0 = jnp.full((b, g, r, qc), _NEG, jnp.float32)
        l0 = jnp.zeros((b, g, r, qc), jnp.float32)
        a0 = jnp.zeros((b, g, r, qc, dv), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_k))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out                                          # (B,G,R,qc,Dv)

    outs = lax.map(one_q_chunk, jnp.arange(n_q))            # (nq,B,G,R,qc,Dv)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, s_len, h, dv)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, cfg: ModelConfig,
                     kv_posit: Optional[str] = None, window: int = 0,
                     start=None, ring: bool = False, apos=None):
    """Single-token decode: q (B,1,H,D); caches (B,T,G,D) possibly posit
    patterns; positions >= cache_len are masked.

    Single-shot formulation (§Perf, dbrx decode): one score einsum over
    the full cache.  The earlier chunked scan sliced the seq-sharded
    cache at a *traced* offset, which GSPMD can only lower by
    all-gathering the entire cache every step (21.5 GB/chip/token on
    dbrx).  With one einsum the T axis stays sharded end-to-end: the
    contraction is local and the softmax reductions across shards are
    (B,H)-sized scalars.  Decode scores are tiny (B*H*T f32), so no
    chunking is needed for memory.

    Masking is rotation- and batch-aware:
    * ``cache_len`` — scalar or (B,) — absolute write frontier; a (B,)
      value gives each batch row its own visible length (ragged batches).
    * ``start`` — scalar or (B,) — first valid absolute position (the
      left-padding offset of each row); positions before it are masked.
    * ``ring=True`` — the cache is a ring buffer of capacity T written at
      ``pos % T``: slot ``i`` holds absolute position
      ``p - ((p - i) mod T)`` for frontier ``p = cache_len - 1``, and the
      validity/window tests run on those rotated absolute positions.
    * ``apos`` — optional (B, T) precomputed absolute positions per cache
      slot (the paged lanes supply these from the block-table layout);
      overrides the linear/ring position computation, everything else —
      masking, softmax, value reduction — is the same math.
    """
    b, _, h, d = q.shape
    t_len, g = k_cache.shape[1], k_cache.shape[2]
    r = h // g
    scale = d ** -0.5

    # §Perf iteration 2 (dbrx decode): materialize the dequantized cache
    # in bf16, not f32 — halves the dominant HBM traffic; the score
    # einsum still accumulates in f32.  (On real TPUs the Pallas
    # posit-codec kernel streams u8->VMEM and this materialization
    # disappears entirely; see kernels/posit_gemm.py.)
    ks, vs = k_cache, v_cache
    if kv_posit is not None:
        ks = posit_to_f32(ks, pcfg(kv_posit))
        vs = posit_to_f32(vs, pcfg(kv_posit))
    ks = ks.astype(cdtype(cfg))
    vs = vs.astype(cdtype(cfg))

    qg = (q.reshape(b, g, r, d) * scale).astype(cdtype(cfg))
    # (refuted §Perf iteration: a bf16 softmax was both slightly *slower*
    # on the memory term (+3%, XLA re-materialized converts) and broke
    # decode-vs-prefill agreement; scores stay f32.)
    scores = jnp.einsum("bgrd,btgd->bgrt", qg, ks,
                        preferred_element_type=jnp.float32)  # (B,G,R,T)
    t_pos = jnp.arange(t_len, dtype=jnp.int32)
    cl = jnp.asarray(cache_len, jnp.int32)
    cl = jnp.broadcast_to(cl, (b,)) if cl.ndim == 0 else cl
    st = jnp.asarray(0 if start is None else start, jnp.int32)
    st = jnp.broadcast_to(st, (b,)) if st.ndim == 0 else st
    if apos is not None:
        apos = jnp.asarray(apos, jnp.int32)
    elif ring:
        p = (cl - 1)[:, None]                               # write frontier
        apos = p - lax.rem(p - t_pos[None, :], t_len)       # (B,T) absolute
    else:
        apos = jnp.broadcast_to(t_pos[None, :], (b, t_len))
    valid = (apos < cl[:, None]) & (apos >= st[:, None])
    if ring:
        valid &= apos >= 0                                  # unwritten slots
    if window:
        valid &= apos >= (cl[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, _NEG)
    m = scores.max(-1, keepdims=True)
    # All-masked guard: a row with NO valid slot (an inactive scheduler
    # slot whose sentinel table entries alias real blocks through the
    # gather clamp) has m == _NEG, so exp(scores - m) == 1 EVERYWHERE —
    # a uniform average of garbage.  Zeroing invalid slots makes such a
    # row finalize to exact zeros (l == 0); rows with a valid slot are
    # bit-identical (finite m already underflowed their masked exp to 0).
    p = jnp.where(valid[:, None, None, :], jnp.exp(scores - m), 0.0)
    l = p.sum(-1)
    out = jnp.einsum("bgrt,btgv->bgrv", p.astype(cdtype(cfg)), vs,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, h, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# Guarded decode-time cache writes
# ---------------------------------------------------------------------------
# ``lax.dynamic_update_slice_in_dim`` CLAMPS out-of-range start indices, so
# an unguarded decode write past the cache capacity silently overwrites the
# last slot — the original serving bug.  Every decode-time cache write goes
# through these two helpers instead: a concrete out-of-capacity index
# raises, a traced one (inside jit/scan, where the engine has already
# checked capacity statically) drops the write rather than clamping.

def check_cache_capacity(pos, capacity: int, what: str = "KV cache"):
    """Raise on a concrete decode position past the cache capacity.

    Traced positions (under jit/scan) cannot raise; there the guarded
    write below degrades to a dropped write — never a clamp-overwrite —
    and the serving engine enforces capacity statically up front.
    """
    from repro.core.tracing import is_tracer
    if is_tracer(pos):
        return
    if int(pos) >= capacity:
        raise ValueError(
            f"decode_step past {what} capacity: position {int(pos)} >= "
            f"{capacity}. Preallocate headroom with init_cache(..., "
            "max_len) / prefill(..., max_len=...) or use "
            "repro.runtime.engine.Engine, which sizes caches up front.")


def guarded_cache_update(arr, upd, idx, axis: int):
    """``dynamic_update_slice_in_dim`` that refuses to clamp: writes at
    ``idx >= capacity`` leave ``arr`` unchanged instead of silently
    overwriting the final slot."""
    new = lax.dynamic_update_slice_in_dim(arr, upd, idx, axis)
    return jnp.where(idx < arr.shape[axis], new, arr)


def roll_cache_time(kv, shift):
    """Circularly shift a stacked-layer KV time axis (L, B, T, ...) by
    ``shift`` slots (traced shifts allowed).

    This is the one primitive behind cache *compaction* and *admission*
    in the continuous-batching scheduler, and it is correct for BOTH
    cache layouts:

    * linear caches: content occupying padded slots ``[len - l, len)``
      moves to ``[len + shift - l, len + shift)``; slots vacated at
      either end hold stale data that the per-row ``lens`` masks already
      exclude (and that a later admission overwrites wholesale);
    * ring buffers (capacity T, writes at ``pos % T``): a frontier move
      of ``shift`` relabels slot ``q % T`` to ``(q + shift) % T`` — the
      circular roll IS that relabelling, no second case needed.
    """
    return jnp.roll(kv, shift, axis=2)


def reset_cache_rows(kv, row_mask, batch_axis: int = 1):
    """Zero the given batch rows of a stacked cache leaf.

    ``row_mask``: (B,) bool, True = clear.  Retired serving slots are
    wiped so a freed row never leaks a previous request's KV into
    reports or debugging dumps (attention already masks it out).
    """
    shape = [1] * kv.ndim
    shape[batch_axis] = row_mask.shape[0]
    return jnp.where(row_mask.reshape(shape), jnp.zeros_like(kv), kv)


def pad_cache_time(kv, t: int):
    """Zero-pad the stacked-layer KV time axis (L,B,S,...) up to ``t`` —
    how prefill turns exactly-prompt-sized KV into a cache with decode
    headroom."""
    s = kv.shape[2]
    if s == t:
        return kv
    pad = [(0, 0)] * kv.ndim
    pad[2] = (0, t - s)
    return jnp.pad(kv, pad)


# ---------------------------------------------------------------------------
# Paged KV cache primitives (block arenas + per-row block tables)
#
# Layout contract (shared with ``compress/kvcache.py`` and the transformer
# paged lanes): arena leaves are (n_blocks, block_size, ...) per layer —
# (L, n_blocks, block_size, ...) stacked — and ``block_tables`` is (B, W)
# int32 with the OUT-OF-RANGE sentinel ``n_blocks`` in unassigned entries.
# Addressing is ROW-LOCAL: row b's token p lives in logical block
# ``p // block_size`` at offset ``p % block_size``.
#
# Two mappings from logical block to table slot:
#   * dense/MLA lane: identity — table slot i IS logical block i
#     (W = ceil(max_len / block_size));
#   * sliding-window lane: a RING over table slots — logical block q maps
#     to slot ``q % W`` with ``W = ceil(window / block_size) + 1``, so a
#     block falling out of the window is recycled in place (the paged
#     re-expression of the ring buffer).  The +1 spare block guarantees
#     every partially-overwritten block's stale half is already outside
#     the window, so masking stale slots as "future" is exact.
# ---------------------------------------------------------------------------


def paged_window_blocks(window: int, block_size: int) -> int:
    """Table width of the sliding-window block ring."""
    return -(-window // block_size) + 1


def paged_is_window_lane(window: int, block_size: int,
                         table_width: int) -> bool:
    """Static lane rule, derivable on both the host (which sizes tables)
    and inside jit (from the table's shape): a paged cache runs the
    block-ring mapping iff its table width equals the window ring's.
    When the dense width coincides the two mappings agree everywhere the
    frontier can reach, so the ambiguity is harmless."""
    return bool(window) and table_width == paged_window_blocks(
        window, block_size)


def paged_positions(frontier, table_width: int, block_size: int, *,
                    window: int = 0):
    """(B,) per-row frontier (last-written position) -> (B, W*bs) absolute
    position of every virtual slot of the gathered paged cache.

    Dense lane: identity.  Window lane: table slot s holds logical block
    ``lb = pb - ((pb - s) mod W)`` for frontier block ``pb``; slots ahead
    of the frontier (or before position 0) get out-of-range positions the
    caller's validity mask excludes — including the stale tail of the
    frontier's own block, whose true (previous-epoch) content is already
    outside the window.
    """
    w, bs = table_width, block_size
    frontier = jnp.asarray(frontier, jnp.int32)
    b = frontier.shape[0]
    offs = jnp.arange(bs, dtype=jnp.int32)
    if paged_is_window_lane(window, bs, w):
        pb = frontier[:, None] // bs                      # (B, 1)
        sblk = jnp.arange(w, dtype=jnp.int32)[None, :]
        lb = pb - lax.rem(pb - sblk, w)                   # (B, W)
        apos = lb[:, :, None] * bs + offs[None, None, :]
    else:
        blk = jnp.arange(w, dtype=jnp.int32)
        apos = (blk[:, None] * bs + offs[None, :])[None]
        apos = jnp.broadcast_to(apos, (b, w, bs))
    return apos.reshape(b, w * bs)


def _arena_head_constraint(x):
    """Pin the head axis of dense paged-KV tensors to the 'model' mesh
    axis: the arena is device_put with heads on 'model'
    (``runtime/sharding.py::paged_cache_specs``), and this constraint on
    the gathered/updated views keeps every paged read and write
    shard-local — decode never all-gathers KV.  MLA latents (no head
    axis, rank-3 views) pass through untouched, as does everything
    outside a mesh context (same no-op contract as
    ``_attn_context_parallel``).  When 'model' does not divide the head
    count the arena itself fell back to replicated
    (``paged_cache_specs``' filter), so the constraint is skipped too —
    a mismatched pin would force GSPMD into full rematerializations."""
    if x.ndim != 4:
        return x
    try:
        from jax.sharding import PartitionSpec as P, get_abstract_mesh
        mesh = get_abstract_mesh()
        n_model = dict(zip(mesh.axis_names, mesh.axis_sizes)).get("model", 1)
        if n_model <= 1 or x.shape[2] % n_model:
            return x
        return lax.with_sharding_constraint(
            x, P(None, None, "model", None))
    except (ValueError, RuntimeError, TypeError, NameError,
            AttributeError, ImportError):
        return x


def paged_gather(arena, tables):
    """arena (n_blocks, bs, ...) + tables (B, W) -> the row-contiguous
    virtual cache (B, W*bs, ...).  Sentinel entries clamp into an
    arbitrary real block; the positions from ``paged_positions`` (or a
    row-local ``lens`` mask) exclude whatever they alias."""
    nb, bs = arena.shape[0], arena.shape[1]
    b, w = tables.shape
    g = jnp.take(arena, jnp.clip(tables, 0, nb - 1), axis=0)
    return _arena_head_constraint(
        g.reshape((b, w * bs) + arena.shape[2:]))


def paged_apos(tables, lens, block_size: int, n_blocks: int, *,
               window: int = 0):
    """Per-slot absolute positions of the virtual paged cache, with dead
    slots marked ``-1``: the one masking contract BOTH paged decode
    paths (fused kernel and gather fallback) consume, so they cannot
    skew.  A slot is dead when its table entry is the sentinel — the
    gather's clamp would alias an arbitrary real block there, and the
    fused kernel's DMA clamps the same way, so both paths must exclude
    it by position.  Live slots keep ``paged_positions``'s row-local
    layout (window lane included)."""
    b, w = tables.shape
    apos = paged_positions(lens, w, block_size, window=window)
    live = jnp.repeat(tables < n_blocks, block_size, axis=1)  # (B, W*bs)
    return jnp.where(live, apos, -1)


def decode_attention_paged(q, k_arena, v_arena, tables, lens, *,
                           cfg: ModelConfig, kv_posit: Optional[str] = None,
                           window: int = 0, kernel: str = "gather",
                           interpret: bool = True):
    """Paged decode attention straight off the block tables.

    q: (B, 1, H, D); arenas (n_blocks, bs, G, D[v]) posit patterns or
    floats; tables (B, W) int32; lens (B,) int32 row frontiers (the
    step's token is already written at ``lens[b]``).

    ``kernel="fused"`` walks the tables inside one Pallas kernel
    (``kernels/posit_paged_attn.py``): posit decode on the VPU, online
    softmax carried in VMEM scratch, sentinel/window masks resolved
    in-kernel — KV patterns cross HBM once.  ``kernel="gather"`` is the
    jnp reference: ``paged_gather`` + :func:`decode_attention`.  Both
    paths consume :func:`paged_apos`, so sentinel-backed slots are
    masked identically and a fully-sentinel row (preempted slot)
    returns exact zeros on either path.
    """
    b, _, h, d = q.shape
    nb, bs, g = k_arena.shape[0], k_arena.shape[1], k_arena.shape[2]
    apos = paged_apos(tables, lens, bs, nb, window=window)
    if kernel == "fused":
        from repro.kernels import posit_paged_attn as K  # lazy: pallas
        qg = (q.reshape(b, g, h // g, d) * d ** -0.5).astype(jnp.float32)
        out = K.paged_decode_attention(
            qg, k_arena, v_arena, tables, apos, lens,
            pcfg=pcfg(kv_posit) if kv_posit else None,
            window=window, interpret=interpret)
        return out.reshape(b, 1, h, -1).astype(q.dtype)
    if kernel != "gather":
        raise ValueError(f"unknown paged decode kernel {kernel!r}")
    return decode_attention(
        q, paged_gather(k_arena, tables), paged_gather(v_arena, tables),
        lens + 1, cfg=cfg, kv_posit=kv_posit, window=window, apos=apos)


def decode_attention_paged_mla(q_lat_eff, q_rope, c_arena, r_arena, tables,
                               lens, *, cfg: ModelConfig,
                               kv_posit: Optional[str] = None,
                               kernel: str = "gather",
                               interpret: bool = True):
    """Absorbed-matrix MLA paged decode: latent-space attention off the
    block tables; returns the latent context (B, H, rank) f32 (the
    caller applies ``wuv``).

    Same kernel dispatch contract as :func:`decode_attention_paged`;
    the fused kernel concatenates the latent (``c``) and decoupled-RoPE
    (``r``) blocks in VMEM and uses the latent block as V.  The gather
    fallback carries the same all-masked guard as
    :func:`decode_attention`: a fully-masked row yields zeros, not the
    uniform garbage average ``jax.nn.softmax`` would produce.
    """
    b, h, _ = q_lat_eff.shape
    nb, bs = c_arena.shape[0], c_arena.shape[1]
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    apos = paged_apos(tables, lens, bs, nb)
    if kernel == "fused":
        from repro.kernels import posit_paged_attn as K  # lazy: pallas
        return K.paged_decode_attention_mla(
            q_lat_eff.astype(jnp.float32), q_rope.astype(jnp.float32),
            c_arena, r_arena, tables, apos, lens,
            pcfg=pcfg(kv_posit) if kv_posit else None,
            scale=scale, interpret=interpret)
    if kernel != "gather":
        raise ValueError(f"unknown paged decode kernel {kernel!r}")
    c = paged_gather(c_arena, tables)                 # (B, W*bs, rank)
    r = paged_gather(r_arena, tables)
    if kv_posit:
        c = posit_to_f32(c, pcfg(kv_posit))
        r = posit_to_f32(r, pcfg(kv_posit))
    c = c.astype(jnp.float32)
    r = r.astype(jnp.float32)
    scores = jnp.einsum("bhr,btr->bht", q_lat_eff.astype(jnp.float32), c)
    scores += jnp.einsum("bhd,btd->bht", q_rope.astype(jnp.float32), r)
    valid = (apos >= 0) & (apos <= lens[:, None])     # content [0, lens]
    scores = jnp.where(valid[:, None, :], scores * scale, _NEG)
    m = scores.max(-1, keepdims=True)
    p = jnp.where(valid[:, None, :], jnp.exp(scores - m), 0.0)
    probs = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bht,btr->bhr", probs, c)       # (B, H, rank)


def paged_cache_update(arena, upd, tables, pos, ok, *, window: int = 0):
    """Scatter one new KV vector per row into its block: row b writes
    ``upd[b]`` at logical position ``pos[b]``.

    The paged guarded write: rows with ``ok=False`` (inactive scheduler
    slots, out-of-capacity positions) and writes through sentinel table
    entries are DROPPED — never clamped onto someone else's block.
    ``upd``: (B, ...) matching the arena's per-slot trailing dims.
    """
    nb, bs = arena.shape[0], arena.shape[1]
    w = tables.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    blk = pos // bs
    if paged_is_window_lane(window, bs, w):
        slot = lax.rem(blk, w)
    else:
        slot = blk
        ok = ok & (blk < w)
    phys = jnp.take_along_axis(
        tables, jnp.clip(slot, 0, w - 1)[:, None], axis=1)[:, 0]
    phys = jnp.where(ok, phys, nb)              # sentinel: scatter drops
    return _arena_head_constraint(
        arena.at[phys, lax.rem(pos, bs)].set(upd, mode="drop"))


def paged_pack(arena, kvs, tables, lens, *, window: int = 0,
               src_shift=None, src_ring: bool = False):
    """Pack prompt KV (L, B, S, ...) into arena blocks (L, nb, bs, ...).

    Row b's content positions ``0..lens[b]-1`` land in the blocks named
    by ``tables[b]`` (sentinel entries drop their scatter — unallocated
    table tails carry garbage that never reaches the arena).  ``src_shift``
    (B,) gives the time-axis index of each row's content start in ``kvs``
    (``S - lens`` for the engine's LEFT-padded prompt batches; default 0
    for batch-1 right-padded caches); ``src_ring`` instead reads a
    ring-layout source at ``pos % S``.  Window-lane tables pack only the
    ring's block span; slots whose positions precede the prompt (or fall
    out of the window) receive garbage that the attention masks exclude,
    exactly as the linear ring does.
    """
    nb, bs = arena.shape[1], arena.shape[2]
    b, s = kvs.shape[1], kvs.shape[2]
    w = tables.shape[1]
    lens = jnp.asarray(lens, jnp.int32)
    # the SAME slot->position mapping decode attention will use, with
    # the prompt's last token as the frontier (one shared definition of
    # the block-ring relabelling, so prefill and decode cannot skew)
    cpos = paged_positions(jnp.maximum(lens - 1, 0), w, bs,
                           window=window).reshape(b, w, bs)
    if src_ring:
        tpos = lax.rem(cpos, s)
    elif src_shift is not None:
        tpos = cpos + jnp.asarray(src_shift, jnp.int32)[:, None, None]
    else:
        tpos = cpos
    tpos = jnp.clip(tpos, 0, s - 1).reshape(b, w * bs)
    idx = tpos.reshape((1, b, w * bs) + (1,) * (kvs.ndim - 3))
    gathered = jnp.take_along_axis(kvs, idx, axis=2)        # (L,B,W*bs,..)
    blocks = gathered.reshape(
        (kvs.shape[0], b * w, bs) + kvs.shape[3:])
    ids = jnp.asarray(tables, jnp.int32).reshape(-1)
    return arena.at[:, ids].set(blocks, mode="drop")


def paged_copy_blocks(arena, src_ids, dst_ids):
    """Copy whole arena blocks: ``arena[:, dst_ids[i]] = arena[:, src_ids[i]]``.

    The device half of copy-on-write: a writer about to touch a block
    it does not exclusively own (refcount > 1) first duplicates the
    posit-pattern leaves block-for-block — no dequantize round-trip,
    the stored patterns move verbatim — then swaps its table entry to
    the private copy.  Sentinel ids in ``dst_ids`` drop their write
    (the usual paged no-clamp rule); ``src_ids`` sentinels clamp into
    an arbitrary block the caller must not reference.
    """
    nb = arena.shape[1]
    src = jnp.clip(jnp.asarray(src_ids, jnp.int32), 0, nb - 1)
    dst = jnp.asarray(dst_ids, jnp.int32)
    blocks = jnp.take(arena, src, axis=1)       # (L, n, bs, ...)
    return arena.at[:, dst].set(blocks, mode="drop")


def paged_poison_blocks(arena, block_ids):
    """Overwrite whole arena blocks with a loud poison pattern.

    The device half of the arena sanitizer: after the :class:`BlockPool`
    physically reclaims blocks, the scheduler poisons them so a stale
    block-table entry (use-after-free the host checks missed) detonates
    the logits instead of silently serving freed KV.  The poison is
    FINITE but absurd — ``-1e30`` for float leaves, the posit maxpos
    pattern for unsigned pattern leaves — because masked-softmax
    correctness relies on ``0 * poison == 0``: NaN poison would leak
    through the ``exp(_NEG) = 0`` attention weights of properly masked
    slots and corrupt healthy rows.  Sentinel ids drop (no-op), so the
    OUT-OF-RANGE entry is always safe to pass.
    """
    if jnp.issubdtype(arena.dtype, jnp.unsignedinteger):
        bits = jnp.iinfo(arena.dtype).bits
        poison = jnp.asarray((1 << (bits - 1)) - 1, arena.dtype)  # maxpos
    else:
        poison = jnp.asarray(-1e30, arena.dtype)
    ids = jnp.asarray(block_ids, jnp.int32)
    return arena.at[:, ids].set(poison, mode="drop")


def paged_pack_range(arena, kvs, tables, start, lens, *, window: int = 0):
    """Pack ONLY positions ``[start, lens)`` of suffix KV into arena
    blocks, preserving every other slot of the touched blocks.

    ``kvs`` is (L, B, S, ...) holding the SUFFIX content: time index
    ``t`` of ``kvs`` is absolute position ``start + t``.  Unlike
    :func:`paged_pack` (which overwrites whole blocks, correct for
    freshly allocated ones), the touched blocks here may already hold
    live content — a COW copy of a shared prefix block whose tail this
    request's recomputed tokens overwrite — so out-of-range slots are
    read back from the arena and written unchanged.  Sentinel table
    entries drop their scatter; the prefix-sharing admission passes the
    sentinel for BORROWED entries so a shared block is never written
    through this path (writes reach borrowed blocks only after COW has
    replaced the table entry).
    """
    nb, bs = arena.shape[1], arena.shape[2]
    b, s = kvs.shape[1], kvs.shape[2]
    w = tables.shape[1]
    lens = jnp.asarray(lens, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    start = jnp.broadcast_to(start, (b,)) if start.ndim == 0 else start
    cpos = paged_positions(jnp.maximum(lens - 1, 0), w, bs,
                           window=window).reshape(b, w, bs)
    tpos = jnp.clip(cpos - start[:, None, None], 0, s - 1)
    tpos = tpos.reshape(b, w * bs)
    idx = tpos.reshape((1, b, w * bs) + (1,) * (kvs.ndim - 3))
    gathered = jnp.take_along_axis(kvs, idx, axis=2)        # (L,B,W*bs,..)
    new = gathered.reshape((kvs.shape[0], b * w, bs) + kvs.shape[3:])
    ids = jnp.asarray(tables, jnp.int32).reshape(-1)
    old = jnp.take(arena, jnp.clip(ids, 0, nb - 1), axis=1)  # (L,B*W,bs,..)
    keep = ((cpos >= start[:, None, None]) &
            (cpos < lens[:, None, None])).reshape(b * w, bs)
    keep = keep.reshape((1, b * w, bs) + (1,) * (kvs.ndim - 3))
    blocks = jnp.where(keep, new, old)
    return arena.at[:, ids].set(blocks, mode="drop")


# ---------------------------------------------------------------------------
# Feed-forward (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": init_dense(k1, d, f),
        "wg": init_dense(k2, d, f),
        "wo": init_dense(k3, f, d),
    }


def mlp(p, x, cfg: ModelConfig):
    gate = dense(p["wg"], x, cfg)
    act = jax.nn.gelu(gate) if cfg.act == "gelu" else jax.nn.silu(gate)
    return dense(p["wo"], act * dense(p["wi"], x, cfg), cfg)


# ---------------------------------------------------------------------------
# Mixture of Experts: sort-based capacity dispatch (no fake-FLOP one-hot
# matmuls), experts sharded over the 'model' axis (EP)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "router": init_dense(k1, d, e, scale=s),
        "wi": jax.random.normal(k2, (e, d, f), jnp.float32) * s,
        "wg": jax.random.normal(k3, (e, d, f), jnp.float32) * s,
        "wo": jax.random.normal(k4, (e, f, d), jnp.float32) * (f ** -0.5),
    }


def _moe_row(p, xt, cfg: ModelConfig):
    """Route one batch row: xt (S, D) -> (S, D).

    Dispatch (top-k -> sort -> fixed-capacity buffers) is row-local, so
    under DP the argsort/bincount/gather never cross devices; only the
    expert einsum (whose capacity dim is sharded over 'model' by the
    caller) touches the TP axis.
    """
    s, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = dense(p["router"], xt, cfg).astype(jnp.float32)  # (S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = lax.top_k(probs, k)                       # (S, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    flat_e = gate_i.reshape(-1)                                # (S*k,)
    order = jnp.argsort(flat_e)                # stable; groups by expert
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    offsets = jnp.cumsum(counts) - counts                      # exclusive
    pos_in_e = jnp.arange(s * k) - offsets[sorted_e]

    cap = _moe_capacity(s, cfg)
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # overflow

    tok_sorted = order // k
    xg = xt[tok_sorted]                                        # (S*k, D)
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[dest].set(
        jnp.where(keep[:, None], xg, 0))
    xe = buf[:-1].reshape(e, cap, d)
    return xe, (order, dest, keep, gate_w)


def _moe_capacity(s: int, cfg: ModelConfig) -> int:
    return int(max(1, (s * cfg.top_k / cfg.n_experts)
                   * cfg.capacity_factor))


def _moe_combine(ye, aux, s, d, dtype, cfg: ModelConfig):
    e, k = cfg.n_experts, cfg.top_k
    cap = ye.shape[1]
    order, dest, keep, gate_w = aux
    y_sorted = ye.reshape(e * cap, d)[jnp.minimum(dest, e * cap - 1)]
    y_sorted = jnp.where(keep[:, None], y_sorted, 0)
    y_flat = jnp.zeros((s * k, d), dtype).at[order].set(y_sorted)
    return (y_flat.reshape(s, k, d)
            * gate_w[..., None].astype(dtype)).sum(axis=1)


def moe(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D).  Row-local top-k dispatch + batched
    expert einsum with the expert/capacity dim sharded over 'model'."""
    b, s, d = x.shape
    # (refuted §Perf iteration: replicating tokens over 'model' before
    # dispatch did NOT remove the backward scatter-add all-reduces —
    # GSPMD reshards the cotangents back to the seq layout regardless.)
    xe, aux = jax.vmap(lambda r: _moe_row(p, r, cfg))(x)   # (B,E,C,D)
    xe = _moe_shard_capacity(xe, cfg)

    wi = maybe_dequant(p["wi"], cfg).astype(x.dtype)
    wg = maybe_dequant(p["wg"], cfg).astype(x.dtype)
    wo = maybe_dequant(p["wo"], cfg).astype(x.dtype)
    hg = jnp.einsum("becd,edf->becf", xe, wg)
    hi = jnp.einsum("becd,edf->becf", xe, wi)
    act = jax.nn.gelu(hg) if cfg.act == "gelu" else jax.nn.silu(hg)
    # §Perf iteration 2: emit the expert output in the compute dtype
    # directly — XLA otherwise runs this dot with an f32 result and
    # defers the bf16 convert until *after* the combine's capacity
    # all-gather, doubling the dominant collective's bytes.
    ye = jnp.einsum("becf,efd->becd", act * hi, wo,
                    preferred_element_type=x.dtype)        # (B,E,C,D)
    ye = _moe_shard_capacity(ye, cfg)

    y = jax.vmap(
        lambda yr, ar: _moe_combine(yr, ar, s, d, x.dtype, cfg))(ye, aux)
    # named so the layer remat policy can SAVE the MoE output: without
    # this, backward re-runs the whole dispatch (gathers + scatter-adds)
    # a second time (§Perf iteration, dbrx train)
    from jax.ad_checkpoint import checkpoint_name
    return checkpoint_name(y, "moe_out")


def _moe_replicate_tokens(x, cfg: ModelConfig):
    try:
        from jax.sharding import PartitionSpec as P
        return lax.with_sharding_constraint(
            x, P(tuple(cfg.batch_axes), None, None))
    except (ValueError, RuntimeError, TypeError, NameError):
        return x


def _moe_shard_capacity(xe, cfg: ModelConfig):
    """Expert-parallel buffer sharding.

    When the expert count divides the TP axis (dbrx: 16 @ 16), shard the
    expert dim — true EP: dispatch becomes an all-to-all against the
    seq-sharded activations and each chip runs only its experts.
    Otherwise (granite-moe: 40 @ 16) shard the *capacity* dim, which
    still splits the expert FLOPs 16 ways with replicated weights.
    """
    try:
        from jax.sharding import PartitionSpec as P, get_abstract_mesh
        mesh = get_abstract_mesh()
        n_model = dict(zip(mesh.axis_names, mesh.axis_sizes)).get("model", 1)
        if n_model > 1 and cfg.n_experts % n_model == 0:
            spec = P(tuple(cfg.batch_axes), "model", None, None)
        else:
            spec = P(tuple(cfg.batch_axes), None, "model", None)
        return lax.with_sharding_constraint(xe, spec)
    except (ValueError, RuntimeError, TypeError, NameError,
            AttributeError, ImportError):
        return xe
