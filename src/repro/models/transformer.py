"""Decoder-only transformer LM (covers 7 of the 10 assigned archs).

Options: GQA/MQA dense attention, MLA (multi-head latent attention,
minicpm3), MoE FFN (granite-moe, dbrx), GeGLU/SwiGLU, vision-stub prefix
(internvl2).  Layer stack is lax.scan'd over stacked params so an
88-layer model lowers to the same HLO size as a 2-layer one.

Protocol (shared by every family in ``repro.models``):
    init_params(key, cfg)                        -> params pytree
    train_loss(params, batch, cfg)               -> scalar loss
    init_cache(cfg, batch, max_len)              -> cache pytree
    prefill(params, tokens, cfg, visual=None,
            max_len=None, ...)                   -> (cache, last_logits)
    decode_step(params, cache, token, cfg)       -> (logits, cache)

``prefill(max_len=...)`` preallocates decode headroom in the returned
cache; without it the cache is prompt-sized and decode_step refuses to
write past it (see the serving section below for the cache layout and
the ring-buffer sliding-window lane).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.convert import f32_to_posit
from . import layers as L
from .config import ModelConfig


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attention(key, cfg: ModelConfig):
    d = cfg.d_model
    if cfg.mla:
        k = jax.random.split(key, 8)
        qh = cfg.qk_nope_dim + cfg.qk_rope_dim
        return {
            "wdq": L.init_dense(k[0], d, cfg.q_lora_rank),
            "q_norm": L.init_rms_norm(cfg.q_lora_rank, cfg),
            "wuq": L.init_dense(k[1], cfg.q_lora_rank, cfg.n_heads * qh),
            "wdkv": L.init_dense(k[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim),
            "kv_norm": L.init_rms_norm(cfg.kv_lora_rank, cfg),
            "wuk": L.init_dense(k[3], cfg.kv_lora_rank,
                                cfg.n_heads * cfg.qk_nope_dim),
            "wuv": L.init_dense(k[4], cfg.kv_lora_rank,
                                cfg.n_heads * cfg.v_head_dim),
            "wo": L.init_dense(k[5], cfg.n_heads * cfg.v_head_dim, d),
        }
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.init_dense(k1, d, cfg.n_heads * cfg.head_dim),
        "wk": L.init_dense(k2, d, cfg.n_kv_heads * cfg.head_dim),
        "wv": L.init_dense(k3, d, cfg.n_kv_heads * cfg.head_dim),
        "wo": L.init_dense(k4, cfg.n_heads * cfg.head_dim, d),
    }


def _init_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.init_rms_norm(cfg.d_model, cfg),
        "attn": _init_attention(k1, cfg),
        "ln2": L.init_rms_norm(cfg.d_model, cfg),
    }
    if cfg.is_moe:
        p["moe"] = L.init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg)
    return p


def init_params(key, cfg: ModelConfig):
    keys = jax.random.split(key, 4)
    layer_keys = jax.random.split(keys[0], cfg.n_layers)
    layers = jax.vmap(lambda k: _init_block(k, cfg))(layer_keys)
    params = {
        "tok_embed": jax.random.normal(
            keys[1], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02,
        "layers": layers,
        "final_norm": L.init_rms_norm(cfg.d_model, cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(keys[2], cfg.d_model, cfg.vocab)
    return params


# ---------------------------------------------------------------------------
# attention forward (dense + MLA)
# ---------------------------------------------------------------------------

def _attn_forward(p, x, positions, cfg: ModelConfig, kv_mask=None):
    b, s, d = x.shape
    if cfg.mla:
        q_lat = L.rms_norm(p["q_norm"], L.dense(p["wdq"], x, cfg), cfg)
        q = L.dense(p["wuq"], q_lat, cfg).reshape(
            b, s, cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim)
        q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
        q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

        dkv = L.dense(p["wdkv"], x, cfg)
        c_kv, k_rope = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
        c_kv = L.rms_norm(p["kv_norm"], c_kv, cfg)
        k_rope = L.apply_rope(k_rope[:, :, None, :], positions,
                              cfg.rope_theta)                   # (B,S,1,r)
        k_nope = L.dense(p["wuk"], c_kv, cfg).reshape(
            b, s, cfg.n_heads, cfg.qk_nope_dim)
        v = L.dense(p["wuv"], c_kv, cfg).reshape(
            b, s, cfg.n_heads, cfg.v_head_dim)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(
                k_rope, (b, s, cfg.n_heads, cfg.qk_rope_dim))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        out = L.flash_attention(q, k, v, causal=True, cfg=cfg,
                                kv_mask=kv_mask)
        out = out.reshape(b, s, cfg.n_heads * cfg.v_head_dim)
        return L.dense(p["wo"], out, cfg), (c_kv, k_rope[:, :, 0, :])

    q = L.dense(p["wq"], x, cfg).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = L.dense(p["wk"], x, cfg).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = L.dense(p["wv"], x, cfg).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    out = L.flash_attention(q, k, v, causal=True, cfg=cfg,
                            window=cfg.sliding_window, kv_mask=kv_mask)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return L.dense(p["wo"], out, cfg), (k, v)


def _block_forward(p, x, positions, cfg: ModelConfig, kv_mask=None):
    a, kv = _attn_forward(p["attn"], L.rms_norm(p["ln1"], x, cfg),
                          positions, cfg, kv_mask=kv_mask)
    x = x + a
    h = L.rms_norm(p["ln2"], x, cfg)
    f = L.moe(p["moe"], h, cfg) if cfg.is_moe else L.mlp(p["mlp"], h, cfg)
    return x + f, kv


def _sp_constraint(x, cfg: ModelConfig):
    """Megatron-SP: keep residual activations sequence-sharded over the
    'model' axis between blocks (no-op without a mesh context)."""
    if not cfg.seq_shard_activations:
        return x
    try:
        from jax.sharding import PartitionSpec as P
        return lax.with_sharding_constraint(
            x, P(tuple(cfg.batch_axes), "model", None))
    except (ValueError, RuntimeError, TypeError, NameError):
        return x


def _run_layers(params, x, positions, cfg: ModelConfig):
    def body(h, lp):
        h = _sp_constraint(h, cfg)
        h, _ = _block_forward(lp, h, positions, cfg)
        return h, None

    if cfg.remat == "layer":
        # save the (small) MoE output so backward does not replay the
        # dispatch gathers/scatters (§Perf, dbrx train)
        policy = jax.checkpoint_policies.save_only_these_names("moe_out") \
            if cfg.is_moe else None
        body = jax.checkpoint(body, policy=policy)
    x, _ = lax.scan(body, x, params["layers"])
    return x


def _embed(params, tokens, cfg: ModelConfig, visual=None):
    x = params["tok_embed"][tokens].astype(L.cdtype(cfg))
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.n_visual_tokens and visual is not None:
        # prepend the (stub) patch embeddings; total length stays S
        nv = cfg.n_visual_tokens
        x = jnp.concatenate(
            [visual.astype(x.dtype), x[:, : x.shape[1] - nv]], axis=1)
    return x


def _unembed_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["tok_embed"].T
    return L.maybe_dequant(params["lm_head"]["w"], cfg)


# ---------------------------------------------------------------------------
# training loss (chunked over sequence to bound logits memory)
# ---------------------------------------------------------------------------

def train_loss(params, batch, cfg: ModelConfig):
    """batch: {'tokens': (B,S) int32, 'mask': (B,S) f32, ['visual': ...]}
    Next-token cross entropy, vocab projection chunked over the sequence."""
    tokens = batch["tokens"]
    mask = batch.get("mask")
    b, s = tokens.shape
    positions = jnp.arange(s)[None, :]
    x = _embed(params, tokens, cfg, batch.get("visual"))
    x = _run_layers(params, x, positions, cfg)
    x = L.rms_norm(params["final_norm"], x, cfg)

    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((b, 1), tokens.dtype)], axis=1)
    label_mask = jnp.ones((b, s), jnp.float32)
    label_mask = label_mask.at[:, -1].set(0.0)
    if mask is not None:
        label_mask = label_mask * mask
    if cfg.n_visual_tokens:
        label_mask = label_mask.at[:, : cfg.n_visual_tokens].set(0.0)

    w = _unembed_weight(params, cfg).astype(x.dtype)
    ck = min(cfg.loss_chunk, s)
    n_chunks = s // ck
    assert s % ck == 0

    def chunk_loss(ci):
        xs = lax.dynamic_slice_in_dim(x, ci * ck, ck, 1)
        ls = lax.dynamic_slice_in_dim(labels, ci * ck, ck, 1)
        ms = lax.dynamic_slice_in_dim(label_mask, ci * ck, ck, 1)
        logits = (xs @ w).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], -1)[..., 0]
        return ((logz - gold) * ms).sum(), ms.sum()

    losses, counts = lax.map(chunk_loss, jnp.arange(n_chunks))
    return losses.sum() / jnp.maximum(counts.sum(), 1.0)


def logits_fn(params, tokens, cfg: ModelConfig, visual=None):
    """Full-sequence logits (small models / examples only)."""
    b, s = tokens.shape
    positions = jnp.arange(s)[None, :]
    x = _embed(params, tokens, cfg, visual)
    x = _run_layers(params, x, positions, cfg)
    x = L.rms_norm(params["final_norm"], x, cfg)
    return (x @ _unembed_weight(params, cfg).astype(x.dtype)).astype(
        jnp.float32)


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode_step
#
# Cache layout (engine-shaped):
#   * K/V time axis is PREALLOCATED to ``max_len`` (or to the sliding
#     window, run as a ring buffer written at ``pos % window``) — decode
#     writes land in headroom instead of clamping onto the last slot.
#   * ``len``     — scalar int32 write frontier (padded coordinates).
#   * ``lens``    — (B,) int32 per-sequence valid token counts; with
#     left-padded ragged prompts ``len - lens[b]`` is row b's padding
#     offset and masks its pad slots out of decode attention.
#   * ``max_len`` — int32 scalar, the preallocated absolute-position
#     budget (cache maintenance ops must pass it through unchanged).
# ---------------------------------------------------------------------------

def _cache_dtype(cfg: ModelConfig):
    if cfg.kv_posit:
        return L.pcfg(cfg.kv_posit).storage_dtype
    return L.cdtype(cfg)


def _cache_meta(batch: int, frontier: int, max_len: int, lens=None):
    if lens is None:
        lens = jnp.full((batch,), frontier, jnp.int32)
    return {
        "len": jnp.asarray(frontier, jnp.int32),
        "lens": jnp.asarray(lens, jnp.int32),
        "max_len": jnp.asarray(max_len, jnp.int32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               window_ring: bool = True):
    """Preallocated decode cache.  ``window_ring=False`` forces a
    full-``max_len`` cache even under a sliding window (the golden
    reference layout the ring buffer is tested against)."""
    meta = _cache_meta(batch, 0, max_len)
    if cfg.mla:
        shape_c = (cfg.n_layers, batch, max_len, cfg.kv_lora_rank)
        shape_r = (cfg.n_layers, batch, max_len, cfg.qk_rope_dim)
        return {
            "c_kv": jnp.zeros(shape_c, _cache_dtype(cfg)),
            "k_rope": jnp.zeros(shape_r, _cache_dtype(cfg)),
            **meta,
        }
    window = cfg.sliding_window or 0
    t = min(max_len, window) if (window and window_ring) else max_len
    shape = (cfg.n_layers, batch, t, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, _cache_dtype(cfg)),
        "v": jnp.zeros(shape, _cache_dtype(cfg)),
        **meta,
    }


def _maybe_quant_kv(x, cfg: ModelConfig):
    if cfg.kv_posit:
        return f32_to_posit(x.astype(jnp.float32), L.pcfg(cfg.kv_posit))
    return x.astype(L.cdtype(cfg))


# ---------------------------------------------------------------------------
# Paged cache lane: block arena + per-row block tables (row-LOCAL
# addressing, so there is no shared padded frontier and nothing to
# compact; see models/layers.py for the layout contract).
# ---------------------------------------------------------------------------

def paged_table_width(cfg: ModelConfig, block_size: int,
                      max_len: int) -> int:
    """Block-table width W: the window ring's ``ceil(window/bs)+1`` when
    a sliding window is active and strictly smaller than the dense
    ``ceil(max_len/bs)``; the dense width otherwise (MLA has no
    window)."""
    dense = -(-int(max_len) // int(block_size))
    if cfg.sliding_window and not cfg.mla:
        ring = L.paged_window_blocks(cfg.sliding_window, block_size)
        if ring < dense:
            return ring
    return dense


def _paged_window(cfg: ModelConfig) -> int:
    return 0 if cfg.mla else (cfg.sliding_window or 0)


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     block_size: int, n_blocks: int):
    """Empty paged pool cache: zeroed arenas, sentinel block tables
    (entries == ``n_blocks``, so writes drop), ``lens`` all zero."""
    w = paged_table_width(cfg, block_size, max_len)
    meta = {
        "block_tables": jnp.full((batch, w), n_blocks, jnp.int32),
        "lens": jnp.zeros((batch,), jnp.int32),
        "max_len": jnp.asarray(max_len, jnp.int32),
    }
    dt = _cache_dtype(cfg)
    if cfg.mla:
        return {
            "c_kv": jnp.zeros(
                (cfg.n_layers, n_blocks, block_size, cfg.kv_lora_rank), dt),
            "k_rope": jnp.zeros(
                (cfg.n_layers, n_blocks, block_size, cfg.qk_rope_dim), dt),
            **meta,
        }
    shape = (cfg.n_layers, n_blocks, block_size, cfg.n_kv_heads,
             cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt), **meta}


def _is_ring(cfg: ModelConfig, capacity: int) -> bool:
    """Window-sized caches run as ring buffers; full-length caches (the
    reference layout, or window >= max_len) stay linear.  When capacity
    equals both the window and max_len the two layouts coincide (the
    frontier never wraps), so the ambiguity is harmless."""
    return bool(cfg.sliding_window) and capacity == cfg.sliding_window


_pad_time = L.pad_cache_time


def _ring_pack(kv, w: int):
    """Fold prompt KV (L,B,S,...) with S > w into ring layout: slot i
    holds the latest absolute position q <= S-1 with q % w == i."""
    s = kv.shape[2]
    idx = jnp.arange(w)
    abs_q = (s - 1) - lax.rem((s - 1) - idx, w)           # all >= s - w >= 0
    return jnp.take(kv, abs_q, axis=2)


def prefill(params, tokens, cfg: ModelConfig, visual=None, *,
            max_len=None, prompt_lens=None, window_ring: bool = True,
            block_size: int = 0, n_blocks: int = 0, block_tables=None):
    """Run the full prompt, return (cache, logits at the last position).

    ``max_len`` preallocates decode headroom (default: no headroom, the
    cache is exactly prompt-sized — decode_step will then refuse to
    write past it instead of clamp-overwriting the last slot).

    ``prompt_lens`` (B,) enables ragged batches: ``tokens`` is
    LEFT-padded to a common length, row b's real tokens occupy the last
    ``prompt_lens[b]`` slots, get RoPE positions 0..len-1, and pad keys
    are masked out of attention for that row only.

    ``block_tables`` (B, W) switches on the PAGED lane: instead of a
    dense (B, max_len) cache, each row's KV is packed into the arena
    blocks its table names (``block_size``/``n_blocks`` size the arena;
    unassigned = sentinel ``n_blocks``, whose scatter is dropped).  The
    KV *values* are identical to the linear lane's — only the storage
    layout changes.
    """
    b, s = tokens.shape
    ml = s if max_len is None else int(max_len)
    if ml < s:
        raise ValueError(f"prefill max_len={ml} < prompt length {s}")
    if prompt_lens is None:
        lens = jnp.full((b,), s, jnp.int32)
        positions = jnp.arange(s)[None, :]
        kv_mask = None
    else:
        lens = jnp.asarray(prompt_lens, jnp.int32)
        positions = jnp.arange(s)[None, :] - (s - lens)[:, None]
        kv_mask = positions >= 0
    x = _embed(params, tokens, cfg, visual)

    def body(h, lp):
        h2, kv = _block_forward(lp, h, positions, cfg, kv_mask=kv_mask)
        return h2, tuple(_maybe_quant_kv(t, cfg) for t in kv)

    body = jax.checkpoint(body) if cfg.remat == "layer" else body
    x, kvs = lax.scan(body, x, params["layers"])
    x = L.rms_norm(params["final_norm"], x, cfg)
    last = x[:, -1:, :]
    logits = (last @ _unembed_weight(params, cfg).astype(x.dtype))
    logits = logits[:, 0, :].astype(jnp.float32)

    if block_tables is not None:
        tables = jnp.asarray(block_tables, jnp.int32)
        empty = init_paged_cache(cfg, b, ml, int(block_size),
                                 int(n_blocks))
        cache = dict(empty, block_tables=tables, lens=lens)
        shift = (s - lens) if prompt_lens is not None else None
        keys = ("c_kv", "k_rope") if cfg.mla else ("k", "v")
        for key, kv in zip(keys, kvs):
            cache[key] = L.paged_pack(
                cache[key], kv, tables, lens,
                window=_paged_window(cfg), src_shift=shift)
        return cache, logits

    meta = _cache_meta(b, s, ml, lens)
    if cfg.mla:
        cache = {"c_kv": _pad_time(kvs[0], ml),
                 "k_rope": _pad_time(kvs[1], ml), **meta}
    else:
        window = cfg.sliding_window or 0
        cap = min(ml, window) if (window and window_ring) else ml
        pack = _ring_pack if s > cap else _pad_time
        cache = {"k": pack(kvs[0], cap), "v": pack(kvs[1], cap), **meta}
    return cache, logits


def _zero_invalid(x, mask):
    """Zero time-axis slots whose (B, T) mask is False.  Gathered arena
    garbage (evicted ring blocks, sentinel clamps, sanitizer poison) is
    finite-but-absurd; zeroing keeps the dead slots' downstream matmuls
    finite, and valid slots are untouched, so it cannot perturb the
    chunked-prefill identity."""
    return jnp.where(mask.reshape(mask.shape + (1,) * (x.ndim - 2)), x, 0)


def _chunk_virtual_tables(tables, lens, bs: int, window: int,
                          virtual_width: int, n_blocks: int):
    """Position-ORDERED virtual block tables for the chunked-prefill
    gather: virtual block ``vb`` of row ``b`` names the physical block
    holding absolute positions ``[vb*bs, (vb+1)*bs)``, or the sentinel.

    Dense/MLA tables are already position-ordered (pad with sentinels to
    the virtual width).  The window ring stores logical block ``q`` at
    slot ``q % W``; pre-chunk, exactly blocks ``lb_max-W+1 .. lb_max``
    (``lb_max = (lens-1)//bs``) hold their latest content, so those map
    through the ring and everything else is the sentinel.  The ring
    invariant ``W*bs >= window + bs`` puts every position below
    ``lb_min*bs`` strictly out of the window of every query at position
    ``>= lens`` — evicted content is never needed.

    Returns ``(vtables (B, virtual_width), low_pos (B,))`` where
    ``low_pos`` is the first position the gather actually covers."""
    b, w = tables.shape
    vw = int(virtual_width)
    if L.paged_is_window_lane(window, bs, w):
        lens = jnp.asarray(lens, jnp.int32)
        lb_max = (lens - 1) // bs                         # -1 at lens == 0
        lb_min = jnp.maximum(lb_max - w + 1, 0)
        vb = jnp.arange(vw, dtype=jnp.int32)[None, :]
        slot = jnp.broadcast_to(lax.rem(vb, w), (b, vw))
        phys = jnp.take_along_axis(tables, slot, axis=1)
        resident = (vb >= lb_min[:, None]) & (vb <= lb_max[:, None])
        vtables = jnp.where(resident, phys, n_blocks)
        return vtables, lb_min * bs
    if vw < w:
        raise ValueError(
            f"chunked prefill virtual width {vw} < table width {w}")
    if vw > w:
        tables = jnp.concatenate(
            [tables, jnp.full((b, vw - w), n_blocks, jnp.int32)], axis=1)
    return tables, jnp.zeros((b,), jnp.int32)


def prefill_chunk(params, cache, tokens, cfg: ModelConfig, n_valid, *,
                  virtual_width: int, write_tables=None):
    """Process ``C`` prompt tokens per row against the PAGED cache — the
    chunked-prefill step that makes every prompt length flow through one
    compiled dispatch shape.

    ``tokens``: (B, C) int32 — row b's next prompt tokens for absolute
    positions ``lens[b] .. lens[b]+C-1``; only the first ``n_valid[b]``
    are real (pad the tail with any valid token id — its KV is computed
    but neither written nor attended).  Rows with ``n_valid == 0`` (idle
    or decode-only slots) are exact no-ops: nothing is written and their
    ``lens`` is unchanged.

    ``virtual_width``: static ``ceil(max_len / block_size)`` — the
    position-ordered virtual cache width every lane gathers (the window
    ring is unfolded into it, see ``_chunk_virtual_tables``).

    ``write_tables``: optional (B, W) tables for the arena WRITE
    (``paged_pack_range``); defaults to ``cache['block_tables']``.  The
    prefix-sharing scheduler passes a copy with borrowed entries
    sentineled so a shared block never takes even a byte-identical
    write-back.

    Returns ``(new_cache, logits)`` with ``logits`` (B, V) taken at each
    row's LAST VALID chunk position (meaningful only for rows whose
    prefill completes in this chunk).

    Numerics — the chunked = whole-prompt identity: ``flash_attention``
    groups KV in fixed ``[i*kc, (i+1)*kc)`` blocks regardless of total
    KV length, every resident position's bytes equal what the full
    prefill computed (exactly, when the KV storage dtype is the compute
    dtype), fresh chunk KV is inserted into the virtual buffer BEFORE
    attention (so it is read pre-codec, like a full prefill), and every
    non-resident slot is replace-masked to the same ``-1e30`` a full
    prefill's causal/window bias produces.  Hence each chunk position's
    hidden state is bit-identical to the whole-prompt path (pinned in
    ``tests/test_paged.py`` / ``tests/test_prefix.py``); with a posit KV
    codec, prior-chunk context is read back through the codec (exactly
    what decode reads), so later chunks can differ from a from-scratch
    prefill in the last ulp.  MoE capacity dispatch sees ``C`` tokens
    per call instead of the whole prompt, so under capacity-pressure
    token dropping the identity only holds for non-MoE configs.
    """
    from repro.core.convert import posit_to_f32
    from repro.core.tracing import is_tracer

    b, c = tokens.shape
    tables = cache["block_tables"]
    arena_key = "c_kv" if cfg.mla else "k"
    nb, bs = cache[arena_key].shape[1], cache[arena_key].shape[2]
    window = _paged_window(cfg)
    lens = jnp.asarray(cache["lens"], jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    lens_after = lens + n_valid
    if not is_tracer(lens_after) and not is_tracer(cache["max_len"]):
        import numpy as _np
        la = _np.asarray(lens_after)
        if la.size and int(la.max()) > int(cache["max_len"]):
            raise ValueError(
                f"prefill_chunk: row frontier {int(la.max())} would "
                f"exceed max_len {int(cache['max_len'])}")

    positions = lens[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    vtables, low_pos = _chunk_virtual_tables(
        tables, lens, bs, window, virtual_width, nb)
    t_len = int(virtual_width) * bs
    apos = jnp.arange(t_len, dtype=jnp.int32)[None, :]    # (1, T)
    resident = (apos < lens[:, None]) & (apos >= low_pos[:, None])
    kv_mask = (apos < lens_after[:, None]) & (apos >= low_pos[:, None])
    bidx = jnp.arange(b)[:, None]

    def load(arena):
        g = L.paged_gather(arena, vtables)                # (B, T, ...)
        if cfg.kv_posit:
            g = posit_to_f32(g, L.pcfg(cfg.kv_posit))
        return _zero_invalid(g.astype(L.cdtype(cfg)), resident)

    def insert(ctx, fresh):
        # scatter row b's fresh chunk at virtual slots lens[b]+j; pad
        # positions past the virtual buffer drop (never clamp)
        return ctx.at[bidx, positions].set(fresh, mode="drop")

    x = _embed(params, tokens, cfg)

    if cfg.mla:
        def body(h, layer):
            lp, c_a, r_a = layer
            hn = L.rms_norm(lp["ln1"], h, cfg)
            q_lat = L.rms_norm(lp["attn"]["q_norm"],
                               L.dense(lp["attn"]["wdq"], hn, cfg), cfg)
            q = L.dense(lp["attn"]["wuq"], q_lat, cfg).reshape(
                b, c, cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim)
            q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
            q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
            q = jnp.concatenate([q_nope, q_rope], -1)

            dkv = L.dense(lp["attn"]["wdkv"], hn, cfg)
            c_suf, r_suf = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
            c_suf = L.rms_norm(lp["attn"]["kv_norm"], c_suf, cfg)
            r_suf = L.apply_rope(r_suf[:, :, None, :], positions,
                                 cfg.rope_theta)[:, :, 0, :]

            c_all = insert(load(c_a), c_suf)              # (B, T, rank)
            r_all = insert(load(r_a), r_suf)
            k_nope = L.dense(lp["attn"]["wuk"], c_all, cfg).reshape(
                b, t_len, cfg.n_heads, cfg.qk_nope_dim)
            v = L.dense(lp["attn"]["wuv"], c_all, cfg).reshape(
                b, t_len, cfg.n_heads, cfg.v_head_dim)
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(
                    r_all[:, :, None, :],
                    (b, t_len, cfg.n_heads, cfg.qk_rope_dim))], -1)
            out = L.flash_attention(q, k, v, causal=True, cfg=cfg,
                                    kv_mask=kv_mask, q_positions=positions)
            out = out.reshape(b, c, cfg.n_heads * cfg.v_head_dim)
            h = h + L.dense(lp["attn"]["wo"], out, cfg)
            hh = L.rms_norm(lp["ln2"], h, cfg)
            f = L.moe(lp["moe"], hh, cfg) if cfg.is_moe else \
                L.mlp(lp["mlp"], hh, cfg)
            return h + f, (_maybe_quant_kv(c_suf, cfg),
                           _maybe_quant_kv(r_suf, cfg))

        x, kv_new = lax.scan(
            body, x, (params["layers"], cache["c_kv"], cache["k_rope"]))
        keys = ("c_kv", "k_rope")
    else:
        def body(h, layer):
            lp, k_a, v_a = layer
            hn = L.rms_norm(lp["ln1"], h, cfg)
            q = L.dense(lp["attn"]["wq"], hn, cfg).reshape(
                b, c, cfg.n_heads, cfg.head_dim)
            k_suf = L.dense(lp["attn"]["wk"], hn, cfg).reshape(
                b, c, cfg.n_kv_heads, cfg.head_dim)
            v_suf = L.dense(lp["attn"]["wv"], hn, cfg).reshape(
                b, c, cfg.n_kv_heads, cfg.head_dim)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k_suf = L.apply_rope(k_suf, positions, cfg.rope_theta)
            k = insert(load(k_a), k_suf)                  # (B, T, G, D)
            v = insert(load(v_a), v_suf)
            out = L.flash_attention(q, k, v, causal=True, cfg=cfg,
                                    window=cfg.sliding_window,
                                    kv_mask=kv_mask, q_positions=positions)
            out = out.reshape(b, c, cfg.n_heads * cfg.head_dim)
            h = h + L.dense(lp["attn"]["wo"], out, cfg)
            hh = L.rms_norm(lp["ln2"], h, cfg)
            f = L.moe(lp["moe"], hh, cfg) if cfg.is_moe else \
                L.mlp(lp["mlp"], hh, cfg)
            return h + f, (_maybe_quant_kv(k_suf, cfg),
                           _maybe_quant_kv(v_suf, cfg))

        x, kv_new = lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        keys = ("k", "v")

    wt = tables if write_tables is None else \
        jnp.asarray(write_tables, jnp.int32)
    new_cache = dict(cache, lens=lens_after)
    for key, kv in zip(keys, kv_new):
        new_cache[key] = L.paged_pack_range(
            cache[key], kv, wt, lens, lens_after, window=window)

    x = L.rms_norm(params["final_norm"], x, cfg)
    last = jnp.take_along_axis(
        x, jnp.clip(n_valid - 1, 0, c - 1)[:, None, None], axis=1)
    logits = (last @ _unembed_weight(params, cfg).astype(x.dtype))
    return new_cache, logits[:, 0, :].astype(jnp.float32)


def _decode_attn_dense(p, x, k_cache, v_cache, pos, lens, cfg: ModelConfig):
    b = x.shape[0]
    capacity = k_cache.shape[1]
    window = cfg.sliding_window or 0
    ring = _is_ring(cfg, capacity)
    q = L.dense(p["wq"], x, cfg).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = L.dense(p["wk"], x, cfg).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = L.dense(p["wv"], x, cfg).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    q = L.apply_rope(q, lens[:, None], cfg.rope_theta)
    k = L.apply_rope(k, lens[:, None], cfg.rope_theta)

    slot = lax.rem(pos, capacity) if ring else pos
    k_cache = L.guarded_cache_update(
        k_cache, _maybe_quant_kv(k, cfg), slot, 1)
    v_cache = L.guarded_cache_update(
        v_cache, _maybe_quant_kv(v, cfg), slot, 1)
    out = L.decode_attention(
        q, k_cache, v_cache, pos + 1, cfg=cfg, kv_posit=cfg.kv_posit,
        window=window, start=pos - lens, ring=ring)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return L.dense(p["wo"], out, cfg), k_cache, v_cache


def _decode_attn_mla(p, x, c_cache, r_cache, pos, lens, cfg: ModelConfig):
    """Absorbed-matrix MLA decode: attend in the compressed latent space."""
    b = x.shape[0]
    q_lat = L.rms_norm(p["q_norm"], L.dense(p["wdq"], x, cfg), cfg)
    q = L.dense(p["wuq"], q_lat, cfg).reshape(
        b, cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = L.apply_rope(q_rope[:, None], lens[:, None],
                          cfg.rope_theta)[:, 0]

    dkv = L.dense(p["wdkv"], x, cfg)                      # (B,1,rank+rope)
    c_new, r_new = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
    c_new = L.rms_norm(p["kv_norm"], c_new, cfg)
    r_new = L.apply_rope(r_new[:, :, None, :], lens[:, None],
                         cfg.rope_theta)[:, :, 0, :]
    c_cache = L.guarded_cache_update(
        c_cache, _maybe_quant_kv(c_new, cfg), pos, 1)
    r_cache = L.guarded_cache_update(
        r_cache, _maybe_quant_kv(r_new, cfg), pos, 1)

    c = c_cache
    r = r_cache
    if cfg.kv_posit:
        from repro.core.convert import posit_to_f32
        c = posit_to_f32(c, L.pcfg(cfg.kv_posit))
        r = posit_to_f32(r, L.pcfg(cfg.kv_posit))
    c = c.astype(jnp.float32)
    r = r.astype(jnp.float32)

    wuk = L.maybe_dequant(p["wuk"]["w"], cfg).reshape(
        cfg.kv_lora_rank, cfg.n_heads, cfg.qk_nope_dim)
    # absorb: q_eff[h] = q_nope[h] @ wuk[:,h,:].T  -> latent-space query
    q_lat_eff = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32), wuk)
    scores = jnp.einsum("bhr,btr->bht", q_lat_eff, c)
    scores += jnp.einsum("bhd,btd->bht", q_rope.astype(jnp.float32), r)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    t_len = c.shape[1]
    t_pos = jnp.arange(t_len)
    valid = (t_pos[None, :] <= pos) & \
        (t_pos[None, :] >= (pos - lens)[:, None])         # (B,T)
    scores = jnp.where(valid[:, None, :], scores * scale, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bht,btr->bhr", probs, c)        # (B,H,rank)
    wuv = L.maybe_dequant(p["wuv"]["w"], cfg).reshape(
        cfg.kv_lora_rank, cfg.n_heads, cfg.v_head_dim)
    out = jnp.einsum("bhr,rhv->bhv", ctx_lat, wuv)
    out = out.reshape(b, 1, cfg.n_heads * cfg.v_head_dim).astype(x.dtype)
    return L.dense(p["wo"], out, cfg), c_cache, r_cache


def _decode_attn_dense_paged(p, x, k_arena, v_arena, tables, lens, ok,
                             cfg: ModelConfig):
    """Paged dense/GQA decode: per-row write position ``lens[b]`` into the
    row's block, then attention straight off the block tables
    (``cfg.paged_attn_kernel`` picks the fused Pallas table walk or the
    gather+jnp reference).  The same projections, RoPE positions
    (content-relative ``lens``) and softmax math as the linear lane —
    only the storage addressing differs, so the scores over valid
    positions are identical."""
    b = x.shape[0]
    window = _paged_window(cfg)
    q = L.dense(p["wq"], x, cfg).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = L.dense(p["wk"], x, cfg).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = L.dense(p["wv"], x, cfg).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    q = L.apply_rope(q, lens[:, None], cfg.rope_theta)
    k = L.apply_rope(k, lens[:, None], cfg.rope_theta)

    k_arena = L.paged_cache_update(
        k_arena, _maybe_quant_kv(k, cfg)[:, 0], tables, lens, ok,
        window=window)
    v_arena = L.paged_cache_update(
        v_arena, _maybe_quant_kv(v, cfg)[:, 0], tables, lens, ok,
        window=window)
    out = L.decode_attention_paged(
        q, k_arena, v_arena, tables, lens, cfg=cfg,
        kv_posit=cfg.kv_posit, window=window,
        kernel=cfg.paged_attn_kernel)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return L.dense(p["wo"], out, cfg), k_arena, v_arena


def _decode_attn_mla_paged(p, x, c_arena, r_arena, tables, lens, ok,
                           cfg: ModelConfig):
    """Paged absorbed-matrix MLA decode (row-local positions);
    ``cfg.paged_attn_kernel`` picks the fused latent-space table walk
    or the gather+jnp reference."""
    b = x.shape[0]
    q_lat = L.rms_norm(p["q_norm"], L.dense(p["wdq"], x, cfg), cfg)
    q = L.dense(p["wuq"], q_lat, cfg).reshape(
        b, cfg.n_heads, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = L.apply_rope(q_rope[:, None], lens[:, None],
                          cfg.rope_theta)[:, 0]

    dkv = L.dense(p["wdkv"], x, cfg)                      # (B,1,rank+rope)
    c_new, r_new = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
    c_new = L.rms_norm(p["kv_norm"], c_new, cfg)
    r_new = L.apply_rope(r_new[:, :, None, :], lens[:, None],
                         cfg.rope_theta)[:, :, 0, :]
    c_arena = L.paged_cache_update(
        c_arena, _maybe_quant_kv(c_new, cfg)[:, 0], tables, lens, ok)
    r_arena = L.paged_cache_update(
        r_arena, _maybe_quant_kv(r_new, cfg)[:, 0], tables, lens, ok)

    wuk = L.maybe_dequant(p["wuk"]["w"], cfg).reshape(
        cfg.kv_lora_rank, cfg.n_heads, cfg.qk_nope_dim)
    q_lat_eff = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32), wuk)
    ctx_lat = L.decode_attention_paged_mla(
        q_lat_eff, q_rope, c_arena, r_arena, tables, lens, cfg=cfg,
        kv_posit=cfg.kv_posit, kernel=cfg.paged_attn_kernel)
    wuv = L.maybe_dequant(p["wuv"]["w"], cfg).reshape(
        cfg.kv_lora_rank, cfg.n_heads, cfg.v_head_dim)
    out = jnp.einsum("bhr,rhv->bhv", ctx_lat, wuv)
    out = out.reshape(b, 1, cfg.n_heads * cfg.v_head_dim).astype(x.dtype)
    return L.dense(p["wo"], out, cfg), c_arena, r_arena


def _decode_step_paged(params, cache, token, cfg: ModelConfig, active):
    """Paged decode: every row writes at its OWN position ``lens[b]`` (no
    shared frontier), inactive rows' writes are dropped and their
    ``lens`` frozen.  Out-of-capacity positions drop too (the no-clamp
    guarantee); concrete frontiers raise eagerly like the linear lane."""
    from repro.core.tracing import is_tracer

    b = token.shape[0]
    lens = jnp.asarray(cache["lens"], jnp.int32)
    tables = cache["block_tables"]
    adv = jnp.ones((b,), jnp.int32) if active is None \
        else jnp.asarray(active).astype(jnp.int32)
    if not is_tracer(lens) and not is_tracer(cache["max_len"]):
        import numpy as _np
        live = _np.asarray(adv).astype(bool)
        if live.any():
            L.check_cache_capacity(
                int(_np.asarray(lens)[live].max()),
                int(cache["max_len"]), "paged KV cache")
    ok = (adv > 0) & (lens < jnp.asarray(cache["max_len"], jnp.int32))
    x = params["tok_embed"][token][:, None, :].astype(L.cdtype(cfg))
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    if cfg.mla:
        def body(h, layer):
            lp, c_a, r_a = layer
            a, c_a, r_a = _decode_attn_mla_paged(
                lp["attn"], L.rms_norm(lp["ln1"], h, cfg), c_a, r_a,
                tables, lens, ok, cfg)
            h = h + a
            hh = L.rms_norm(lp["ln2"], h, cfg)
            f = L.moe(lp["moe"], hh, cfg) if cfg.is_moe else \
                L.mlp(lp["mlp"], hh, cfg)
            return h + f, (c_a, r_a)

        x, (c_new, r_new) = lax.scan(
            body, x, (params["layers"], cache["c_kv"], cache["k_rope"]))
        new_cache = dict(cache, c_kv=c_new, k_rope=r_new, lens=lens + adv)
    else:
        def body(h, layer):
            lp, k_a, v_a = layer
            a, k_a, v_a = _decode_attn_dense_paged(
                lp["attn"], L.rms_norm(lp["ln1"], h, cfg), k_a, v_a,
                tables, lens, ok, cfg)
            h = h + a
            hh = L.rms_norm(lp["ln2"], h, cfg)
            f = L.moe(lp["moe"], hh, cfg) if cfg.is_moe else \
                L.mlp(lp["mlp"], hh, cfg)
            return h + f, (k_a, v_a)

        x, (k_new, v_new) = lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = dict(cache, k=k_new, v=v_new, lens=lens + adv)

    x = L.rms_norm(params["final_norm"], x, cfg)
    logits = (x[:, 0, :] @ _unembed_weight(params, cfg).astype(x.dtype))
    return logits.astype(jnp.float32), new_cache


def _decode_lens(cache, pos, batch: int):
    lens = cache.get("lens")
    if lens is None:                         # legacy cache without metadata
        lens = jnp.broadcast_to(pos, (batch,))
    return lens


def decode_step(params, cache, token, cfg: ModelConfig, active=None):
    """token: (B,) int32 -> (logits (B,V) f32, new cache).

    ``active``: optional (B,) bool — rows that hold a live request.  The
    shared padded frontier ``len`` always advances (every row is written
    at the same slot), but an inactive row's ``lens`` stays put, so an
    empty scheduler slot never accretes phantom valid tokens: its
    attention window stays pinned to the (masked) frontier and, crucially,
    its ``lens`` cannot hold ``compact`` back from reclaiming headroom.
    Inactive rows still produce (discarded) logits — batched decode has
    no per-row early exit.

    Paged caches (a ``block_tables`` leaf) take the row-local lane:
    every row writes at its own ``lens[b]`` inside its own blocks, so
    there is no shared frontier to advance (and no ``len`` leaf).
    """
    if "block_tables" in cache:
        return _decode_step_paged(params, cache, token, cfg, active)
    pos = cache["len"]
    b = token.shape[0]
    lens = _decode_lens(cache, pos, b)
    adv = jnp.ones((b,), jnp.int32) if active is None \
        else jnp.asarray(active).astype(jnp.int32)
    x = params["tok_embed"][token][:, None, :].astype(L.cdtype(cfg))
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    if cfg.mla:
        L.check_cache_capacity(pos, cache["c_kv"].shape[2],
                               "MLA latent cache")

        def body(h, layer):
            lp, c_c, r_c = layer
            a, c_c, r_c = _decode_attn_mla(
                lp["attn"], L.rms_norm(lp["ln1"], h, cfg), c_c, r_c,
                pos, lens, cfg)
            h = h + a
            hh = L.rms_norm(lp["ln2"], h, cfg)
            f = L.moe(lp["moe"], hh, cfg) if cfg.is_moe else \
                L.mlp(lp["mlp"], hh, cfg)
            return h + f, (c_c, r_c)

        x, (c_new, r_new) = lax.scan(
            body, x, (params["layers"], cache["c_kv"], cache["k_rope"]))
        new_cache = dict(cache, c_kv=c_new, k_rope=r_new,
                         len=pos + 1, lens=lens + adv)
    else:
        capacity = cache["k"].shape[2]
        if not _is_ring(cfg, capacity):
            L.check_cache_capacity(pos, capacity)

        def body(h, layer):
            lp, k_c, v_c = layer
            a, k_c, v_c = _decode_attn_dense(
                lp["attn"], L.rms_norm(lp["ln1"], h, cfg), k_c, v_c,
                pos, lens, cfg)
            h = h + a
            hh = L.rms_norm(lp["ln2"], h, cfg)
            f = L.moe(lp["moe"], hh, cfg) if cfg.is_moe else \
                L.mlp(lp["mlp"], hh, cfg)
            return h + f, (k_c, v_c)

        x, (k_new, v_new) = lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = dict(cache, k=k_new, v=v_new, len=pos + 1,
                         lens=lens + adv)

    x = L.rms_norm(params["final_norm"], x, cfg)
    logits = (x[:, 0, :] @ _unembed_weight(params, cfg).astype(x.dtype))
    return logits.astype(jnp.float32), new_cache
