"""Whisper-style encoder-decoder (audio backbone; conv frontend is a STUB
per the assignment: ``input_specs`` provides precomputed frame embeddings
of shape (B, encoder_seq, d_model)).

Architecture: sinusoidal-position encoder with bidirectional attention;
decoder with learned positions, causal self-attention + cross-attention.
LayerNorm + GELU, faithful to arXiv:2212.04356.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .config import ModelConfig


def _sinusoids(length, channels):
    t = jnp.arange(length, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.arange(channels // 2, dtype=jnp.float32)
                  * (jnp.log(10000.0) / (channels // 2 - 1)))
    ang = t * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_attn(key, cfg: ModelConfig):
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.init_dense(k1, d, cfg.n_heads * cfg.head_dim, bias=True),
        "wk": L.init_dense(k2, d, cfg.n_kv_heads * cfg.head_dim),
        "wv": L.init_dense(k3, d, cfg.n_kv_heads * cfg.head_dim, bias=True),
        "wo": L.init_dense(k4, cfg.n_heads * cfg.head_dim, d, bias=True),
    }


def _init_mlp(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "wi": L.init_dense(k1, cfg.d_model, cfg.d_ff, bias=True),
        "wo": L.init_dense(k2, cfg.d_ff, cfg.d_model, bias=True),
    }


def _mlp(p, x, cfg):
    return L.dense(p["wo"], jax.nn.gelu(L.dense(p["wi"], x, cfg)), cfg)


def init_params(key, cfg: ModelConfig):
    d = cfg.d_model

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": L.init_layer_norm(d), "attn": _init_attn(k1, cfg),
                "ln2": L.init_layer_norm(d), "mlp": _init_mlp(k2, cfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": L.init_layer_norm(d), "self": _init_attn(k1, cfg),
                "ln_x": L.init_layer_norm(d), "cross": _init_attn(k2, cfg),
                "ln2": L.init_layer_norm(d), "mlp": _init_mlp(k3, cfg)}

    ks = jax.random.split(key, 6)
    n_enc = cfg.encoder_layers or cfg.n_layers
    return {
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(ks[0], n_enc)),
        "enc_ln": L.init_layer_norm(d),
        "tok_embed": jax.random.normal(ks[1], (cfg.vocab, d)) * 0.02,
        "pos_embed": jax.random.normal(ks[2], (4096 * 8, d)) * 0.01,
        "dec_layers": jax.vmap(dec_layer)(
            jax.random.split(ks[3], cfg.n_layers)),
        "dec_ln": L.init_layer_norm(d),
    }


def _qkv(p, x, cfg, positions=None):
    b, s, _ = x.shape
    q = L.dense(p["wq"], x, cfg).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = L.dense(p["wk"], x, cfg).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = L.dense(p["wv"], x, cfg).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, T_enc, d) precomputed embeddings (conv stub output)."""
    x = frames.astype(L.cdtype(cfg))
    x = x + _sinusoids(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def body(h, lp):
        xin = L.layer_norm(lp["ln1"], h)
        q, k, v = _qkv(lp["attn"], xin, cfg)
        a = L.flash_attention(q, k, v, causal=False, cfg=cfg)
        a = a.reshape(h.shape[0], h.shape[1], -1)
        h = h + L.dense(lp["attn"]["wo"], a, cfg)
        h = h + _mlp(lp["mlp"], L.layer_norm(lp["ln2"], h), cfg)
        return h, None

    if cfg.remat == "layer":
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["enc_layers"])
    return L.layer_norm(params["enc_ln"], x)


def _decoder(params, tokens, enc_out, cfg: ModelConfig):
    b, s = tokens.shape
    x = params["tok_embed"][tokens].astype(L.cdtype(cfg))
    x = x + params["pos_embed"][:s].astype(x.dtype)[None]

    def body(h, lp):
        xin = L.layer_norm(lp["ln1"], h)
        q, k, v = _qkv(lp["self"], xin, cfg)
        a = L.flash_attention(q, k, v, causal=True, cfg=cfg)
        h = h + L.dense(lp["self"]["wo"], a.reshape(b, s, -1), cfg)
        xin = L.layer_norm(lp["ln_x"], h)
        q = L.dense(lp["cross"]["wq"], xin, cfg).reshape(
            b, s, cfg.n_heads, cfg.head_dim)
        ek = L.dense(lp["cross"]["wk"], enc_out, cfg).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        ev = L.dense(lp["cross"]["wv"], enc_out, cfg).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        c = L.flash_attention(q, ek, ev, causal=False, cfg=cfg)
        h = h + L.dense(lp["cross"]["wo"], c.reshape(b, s, -1), cfg)
        h = h + _mlp(lp["mlp"], L.layer_norm(lp["ln2"], h), cfg)
        return h, None

    if cfg.remat == "layer":
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["dec_layers"])
    return L.layer_norm(params["dec_ln"], x)


def train_loss(params, batch, cfg: ModelConfig):
    """batch: {'tokens': (B,S), 'frames': (B,T_enc,d), 'mask': optional}."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    enc_out = encode(params, batch["frames"], cfg)
    x = _decoder(params, tokens, enc_out, cfg)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((b, 1), tokens.dtype)], axis=1)
    mask = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
    w = params["tok_embed"].T.astype(x.dtype)     # whisper ties the head
    ck = min(cfg.loss_chunk, s)

    def chunk_loss(ci):
        xs = lax.dynamic_slice_in_dim(x, ci * ck, ck, 1)
        ls = lax.dynamic_slice_in_dim(labels, ci * ck, ck, 1)
        ms = lax.dynamic_slice_in_dim(mask, ci * ck, ck, 1)
        logits = (xs @ w).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], -1)[..., 0]
        return ((logz - gold) * ms).sum(), ms.sum()

    losses, counts = lax.map(chunk_loss, jnp.arange(s // ck))
    return losses.sum() / jnp.maximum(counts.sum(), 1.0)


def logits_fn(params, tokens, cfg: ModelConfig, frames=None):
    enc_out = encode(params, frames, cfg)
    x = _decoder(params, tokens, enc_out, cfg)
    return (x @ params["tok_embed"].T.astype(x.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def _cache_dtype(cfg: ModelConfig):
    if cfg.kv_posit:
        return L.pcfg(cfg.kv_posit).storage_dtype
    return L.cdtype(cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    t_enc = cfg.encoder_seq
    dt = _cache_dtype(cfg)
    kv = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    ckv = (cfg.n_layers, batch, t_enc, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
        "ck": jnp.zeros(ckv, dt), "cv": jnp.zeros(ckv, dt),
        "len": jnp.zeros((), jnp.int32),
        "lens": jnp.zeros((batch,), jnp.int32),
        "max_len": jnp.asarray(max_len, jnp.int32),
    }


_pad_time = L.pad_cache_time


def prefill(params, tokens, cfg: ModelConfig, frames=None, *,
            max_len=None):
    """Encode audio; precompute cross-attention KV; run the prompt tokens
    through the decoder caching self-attention KV.  ``max_len``
    preallocates decode headroom on the self-attention cache."""
    from repro.core.convert import f32_to_posit

    def quant(t):
        if cfg.kv_posit:
            return f32_to_posit(t.astype(jnp.float32), L.pcfg(cfg.kv_posit))
        return t.astype(L.cdtype(cfg))

    b, s = tokens.shape
    enc_out = encode(params, frames, cfg)
    x = params["tok_embed"][tokens].astype(L.cdtype(cfg))
    x = x + params["pos_embed"][:s].astype(x.dtype)[None]

    def body(h, lp):
        xin = L.layer_norm(lp["ln1"], h)
        q, k, v = _qkv(lp["self"], xin, cfg)
        a = L.flash_attention(q, k, v, causal=True, cfg=cfg)
        h = h + L.dense(lp["self"]["wo"], a.reshape(b, s, -1), cfg)
        xin = L.layer_norm(lp["ln_x"], h)
        q = L.dense(lp["cross"]["wq"], xin, cfg).reshape(
            b, s, cfg.n_heads, cfg.head_dim)
        ek = L.dense(lp["cross"]["wk"], enc_out, cfg).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        ev = L.dense(lp["cross"]["wv"], enc_out, cfg).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, cfg.head_dim)
        c = L.flash_attention(q, ek, ev, causal=False, cfg=cfg)
        h = h + L.dense(lp["cross"]["wo"], c.reshape(b, s, -1), cfg)
        h = h + _mlp(lp["mlp"], L.layer_norm(lp["ln2"], h), cfg)
        return h, (quant(k), quant(v), quant(ek), quant(ev))

    x, (ks, vs, cks, cvs) = lax.scan(body, x, params["dec_layers"])
    x = L.layer_norm(params["dec_ln"], x)
    logits = (x[:, -1, :] @ params["tok_embed"].T.astype(x.dtype))
    ml = s if max_len is None else int(max_len)
    if ml < s:
        raise ValueError(f"prefill max_len={ml} < prompt length {s}")
    cache = {"k": _pad_time(ks, ml), "v": _pad_time(vs, ml),
             "ck": cks, "cv": cvs,
             "len": jnp.asarray(s, jnp.int32),
             "lens": jnp.full((b,), s, jnp.int32),
             "max_len": jnp.asarray(ml, jnp.int32)}
    return cache, logits.astype(jnp.float32)


def decode_step(params, cache, token, cfg: ModelConfig):
    from repro.core.convert import f32_to_posit
    pos = cache["len"]
    b = token.shape[0]
    L.check_cache_capacity(pos, cache["k"].shape[2],
                           "decoder self-attention cache")
    x = params["tok_embed"][token][:, None, :].astype(L.cdtype(cfg))
    x = x + lax.dynamic_slice_in_dim(
        params["pos_embed"], pos, 1, 0).astype(x.dtype)[None, 0]

    def quant(t):
        if cfg.kv_posit:
            return f32_to_posit(t.astype(jnp.float32), L.pcfg(cfg.kv_posit))
        return t.astype(L.cdtype(cfg))

    def body(h, layer):
        lp, k_c, v_c, ck_c, cv_c = layer
        xin = L.layer_norm(lp["ln1"], h)
        q, k, v = _qkv(lp["self"], xin, cfg)
        k_c = L.guarded_cache_update(k_c, quant(k), pos, 1)
        v_c = L.guarded_cache_update(v_c, quant(v), pos, 1)
        a = L.decode_attention(q, k_c, v_c, pos + 1, cfg=cfg,
                               kv_posit=cfg.kv_posit)
        h = h + L.dense(lp["self"]["wo"], a.reshape(b, 1, -1), cfg)
        xin = L.layer_norm(lp["ln_x"], h)
        q = L.dense(lp["cross"]["wq"], xin, cfg).reshape(
            b, 1, cfg.n_heads, cfg.head_dim)
        c = L.decode_attention(q, ck_c, cv_c, ck_c.shape[1], cfg=cfg,
                               kv_posit=cfg.kv_posit)
        h = h + L.dense(lp["cross"]["wo"], c.reshape(b, 1, -1), cfg)
        h = h + _mlp(lp["mlp"], L.layer_norm(lp["ln2"], h), cfg)
        return h, (k_c, v_c)

    x, (k_new, v_new) = lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]))
    x = L.layer_norm(params["dec_ln"], x)
    logits = (x[:, 0, :] @ params["tok_embed"].T.astype(x.dtype))
    new_cache = dict(cache, k=k_new, v=v_new, len=pos + 1)
    if "lens" in cache:
        new_cache["lens"] = cache["lens"] + 1
    return logits.astype(jnp.float32), new_cache
