"""RWKV6 "Finch" — attention-free LM with data-dependent decay.

Faithful structure: token-shift with data-dependent mixing (5-way LoRA),
WKV recurrence  S_t = diag(w_t) S_{t-1} + k_t^T v_t,
out_t = r_t (S_{t-1} + diag(u) k_t^T v_t),  per-head group norm, output
gate, and the squared-ReLU channel-mix.  Decay w_t = exp(-exp(...)) is
data-dependent (w0 + LoRA).

Two WKV engines:
* ``wkv_scan``    — step-by-step reference (used by tests / decode).
* ``wkv_chunked`` — chunk-parallel form in log-decay space (all exponents
  <= 0, so no overflow); (C, C, N) ratio tensors are materialized per
  chunk which bounds the working set; used for training/prefill.

Posit note (DESIGN.md §4): no KV cache exists — the O(1) state is the
whole memory; the paper's codec applies to weights/gradients only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .config import ModelConfig


def _heads(cfg: ModelConfig):
    n = cfg.head_dim                       # key/value head size (64)
    h = cfg.n_heads
    return h, n, h * n


def init_params(key, cfg: ModelConfig):
    h, n, d_att = _heads(cfg)
    d, ff = cfg.d_model, cfg.d_ff
    lora = cfg.decay_lora

    def init_layer(k):
        ks = jax.random.split(k, 12)
        s = d ** -0.5
        return {
            "ln1": L.init_layer_norm(d),
            "ln2": L.init_layer_norm(d),
            # token-shift mixing coefficients + data-dependent LoRA
            "maa_x": jnp.zeros((d,), jnp.float32),
            "maa_wkvrg": jnp.zeros((5, d), jnp.float32),
            "tm_w1": jax.random.normal(ks[0], (d, 5 * lora)) * s,
            "tm_w2": jax.random.normal(ks[1], (5, lora, d)) * (lora ** -0.5),
            # decay
            "w0": jnp.full((d_att,), -6.0, jnp.float32),
            "wl_a": jax.random.normal(ks[2], (d, lora)) * s,
            "wl_b": jax.random.normal(ks[3], (lora, d_att)) * (lora ** -0.5),
            "u": jnp.zeros((h, n), jnp.float32),
            "wr": L.init_dense(ks[4], d, d_att),
            "wk": L.init_dense(ks[5], d, d_att),
            "wv": L.init_dense(ks[6], d, d_att),
            "wg": L.init_dense(ks[7], d, d_att),
            "ln_x": L.init_layer_norm(d_att),
            "wo": L.init_dense(ks[8], d_att, d),
            # channel mix
            "cm_maa_k": jnp.zeros((d,), jnp.float32),
            "cm_maa_r": jnp.zeros((d,), jnp.float32),
            "cm_wk": L.init_dense(ks[9], d, ff),
            "cm_wv": L.init_dense(ks[10], ff, d),
            "cm_wr": L.init_dense(ks[11], d, d),
        }

    keys = jax.random.split(key, 4)
    layer_keys = jax.random.split(keys[0], cfg.n_layers)
    return {
        "tok_embed": jax.random.normal(
            keys[1], (cfg.vocab, d), jnp.float32) * 0.02,
        "ln0": L.init_layer_norm(d),
        "layers": jax.vmap(init_layer)(layer_keys),
        "ln_out": L.init_layer_norm(d),
        "lm_head": L.init_dense(keys[2], d, cfg.vocab),
    }


# ---------------------------------------------------------------------------
# WKV engines
# ---------------------------------------------------------------------------

def wkv_scan(r, k, v, w, u, state):
    """Reference recurrence.  r,k,v,w: (B,S,H,N); u: (H,N);
    state: (B,H,N,V=N).  Returns (out (B,S,H,N), new state)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                     # (B,H,N)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, outs = lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 1), state


def wkv_chunked(r, k, v, w, u, state, chunk: int):
    """Chunk-parallel WKV in log-decay space (see module docstring)."""
    b, s, h, n = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    lw = jnp.log(jnp.maximum(w, 1e-38))              # <= 0

    def shape(t):
        return t.reshape(b, nc, chunk, h, n).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = shape(r), shape(k), shape(v), shape(lw)  # (nc,B,H,C,N)

    def per_chunk(st, inp):
        rr, kk, vv, ll = inp                         # (B,H,C,N)
        li = jnp.cumsum(ll, axis=2)                  # inclusive logs
        lx = li - ll                                 # exclusive
        # intra: A[c,j] = sum_n r[c] k[j] exp(lx[c] - li[j]),  j < c
        diff = lx[:, :, :, None, :] - li[:, :, None, :, :]   # (B,H,C,C,N)
        cmask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        ratio = jnp.where(cmask[None, None, :, :, None],
                          jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        amat = jnp.einsum("bhcn,bhjn,bhcjn->bhcj", rr, kk, ratio)
        # diagonal bonus term
        bonus = jnp.einsum("bhcn,bhcn->bhc", rr * u[None, :, None, :], kk)
        out = jnp.einsum("bhcj,bhjv->bhcv", amat, vv)
        out += bonus[..., None] * vv
        # inter: r[c] * exp(lx[c]) against the carried state
        out += jnp.einsum("bhcn,bhnv->bhcv", rr * jnp.exp(lx), st)
        # state update: S = exp(L_C) S + sum_j exp(L_C - li[j]) k_j^T v_j
        l_tot = li[:, :, -1:, :]                     # (B,H,1,N)
        kscale = kk * jnp.exp(l_tot - li)
        st = jnp.exp(l_tot[:, :, 0, :, None]) * st + jnp.einsum(
            "bhjn,bhjv->bhnv", kscale, vv)
        return st, out

    state, outs = lax.scan(per_chunk, state, (rc, kc, vc, lwc))
    return (outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, n), state)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _token_shift(x, prev):
    """prev: (B,D) hidden of the token before this window."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _time_mix(p, x, prev_x, wkv_state, cfg: ModelConfig, *, use_chunked):
    b, s, d = x.shape
    h, n, d_att = _heads(cfg)
    xx = _token_shift(x, prev_x)
    sx = xx - x
    xxx = x + sx * p["maa_x"].astype(x.dtype)
    mix = jnp.tanh(xxx @ p["tm_w1"].astype(x.dtype))     # (B,S,5*lora)
    mix = mix.reshape(b, s, 5, -1).transpose(2, 0, 1, 3)
    mods = jnp.einsum("fbsl,fld->fbsd", mix,
                      p["tm_w2"].astype(x.dtype))        # (5,B,S,D)
    mw, mk, mv, mr, mg = mods + p["maa_wkvrg"][:, None, None, :].astype(x.dtype)
    xw, xk, xv, xr, xg = (x + sx * m for m in (mw, mk, mv, mr, mg))

    rr = L.dense(p["wr"], xr, cfg).reshape(b, s, h, n)
    kk = L.dense(p["wk"], xk, cfg).reshape(b, s, h, n)
    vv = L.dense(p["wv"], xv, cfg).reshape(b, s, h, n)
    gg = jax.nn.silu(L.dense(p["wg"], xg, cfg))

    dlog = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["wl_a"].astype(x.dtype)) @
        p["wl_b"].astype(x.dtype)).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dlog)).reshape(b, s, h, n)      # in (0,1)

    rr32, kk32, vv32 = (t.astype(jnp.float32) for t in (rr, kk, vv))
    u = p["u"].astype(jnp.float32)
    if use_chunked:
        out, wkv_state = wkv_chunked(rr32, kk32, vv32, w, u, wkv_state,
                                     cfg.wkv_chunk)
    else:
        out, wkv_state = wkv_scan(rr32, kk32, vv32, w, u, wkv_state)

    out = out.reshape(b, s, d_att)
    out = L.layer_norm(p["ln_x"], out, cfg.norm_eps).astype(x.dtype)
    out = L.dense(p["wo"], out * gg, cfg)
    return out, x[:, -1, :], wkv_state


def _channel_mix(p, x, prev_x, cfg: ModelConfig):
    xx = _token_shift(x, prev_x)
    sx = xx - x
    xk = x + sx * p["cm_maa_k"].astype(x.dtype)
    xr = x + sx * p["cm_maa_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(L.dense(p["cm_wk"], xk, cfg)))
    out = jax.nn.sigmoid(L.dense(p["cm_wr"], xr, cfg)) * \
        L.dense(p["cm_wv"], kk, cfg)
    return out, x[:, -1, :]


def _forward(params, tokens, cfg: ModelConfig, *, use_chunked=True):
    b, s = tokens.shape
    h, n, _ = _heads(cfg)
    x = params["tok_embed"][tokens].astype(L.cdtype(cfg))
    x = L.layer_norm(params["ln0"], x, cfg.norm_eps)

    zeros_prev = jnp.zeros((b, cfg.d_model), x.dtype)
    zero_state = jnp.zeros((b, h, n, n), jnp.float32)

    def body(hid, lp):
        a, _, _ = _time_mix(lp, L.layer_norm(lp["ln1"], hid, cfg.norm_eps),
                            zeros_prev, zero_state, cfg,
                            use_chunked=use_chunked)
        hid = hid + a
        c, _ = _channel_mix(lp, L.layer_norm(lp["ln2"], hid, cfg.norm_eps),
                            zeros_prev, cfg)
        return hid + c, None

    if cfg.remat == "layer":
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["layers"])
    return L.layer_norm(params["ln_out"], x, cfg.norm_eps)


def train_loss(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _forward(params, tokens, cfg)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((b, 1), tokens.dtype)], axis=1)
    mask = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
    if batch.get("mask") is not None:
        mask = mask * batch["mask"]
    w = params["lm_head"]["w"].astype(x.dtype)
    ck = min(cfg.loss_chunk, s)
    n_chunks = s // ck

    def chunk_loss(ci):
        xs = lax.dynamic_slice_in_dim(x, ci * ck, ck, 1)
        ls = lax.dynamic_slice_in_dim(labels, ci * ck, ck, 1)
        ms = lax.dynamic_slice_in_dim(mask, ci * ck, ck, 1)
        logits = (xs @ w).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], -1)[..., 0]
        return ((logz - gold) * ms).sum(), ms.sum()

    losses, counts = lax.map(chunk_loss, jnp.arange(n_chunks))
    return losses.sum() / jnp.maximum(counts.sum(), 1.0)


def logits_fn(params, tokens, cfg: ModelConfig, visual=None):
    x = _forward(params, tokens, cfg, use_chunked=False)
    return (x @ params["lm_head"]["w"].astype(x.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# serving: O(1) state instead of a KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    del max_len                                       # state is O(1)!
    h, n, _ = _heads(cfg)
    lshape = (cfg.n_layers, batch)
    return {
        "wkv": jnp.zeros((*lshape, h, n, n), jnp.float32),
        "tm_x": jnp.zeros((*lshape, cfg.d_model), jnp.float32),
        "cm_x": jnp.zeros((*lshape, cfg.d_model), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cache, token, cfg: ModelConfig):
    x = params["tok_embed"][token][:, None, :].astype(L.cdtype(cfg))
    x = L.layer_norm(params["ln0"], x, cfg.norm_eps)

    def body(hid, layer):
        lp, wkv_s, tm_prev, cm_prev = layer
        a, tm_new, wkv_s = _time_mix(
            lp, L.layer_norm(lp["ln1"], hid, cfg.norm_eps),
            tm_prev.astype(hid.dtype), wkv_s, cfg, use_chunked=False)
        hid = hid + a
        c, cm_new = _channel_mix(
            lp, L.layer_norm(lp["ln2"], hid, cfg.norm_eps),
            cm_prev.astype(hid.dtype), cfg)
        return hid + c, (wkv_s, tm_new.astype(jnp.float32),
                         cm_new.astype(jnp.float32))

    x, (wkv_new, tm_new, cm_new) = lax.scan(
        body, x, (params["layers"], cache["wkv"], cache["tm_x"],
                  cache["cm_x"]))
    x = L.layer_norm(params["ln_out"], x, cfg.norm_eps)
    logits = (x[:, 0, :] @ params["lm_head"]["w"].astype(x.dtype))
    new_cache = {"wkv": wkv_new, "tm_x": tm_new, "cm_x": cm_new,
                 "len": cache["len"] + 1}
    return logits.astype(jnp.float32), new_cache


def prefill(params, tokens, cfg: ModelConfig, visual=None, *,
            max_len=None):
    """Prefill = forward pass threading the recurrent state through.

    ``max_len`` is accepted for protocol uniformity and ignored: the
    recurrent state is O(1), so there is no cache to preallocate and
    decode can never run out of capacity."""
    del max_len
    b, s = tokens.shape
    h, n, _ = _heads(cfg)
    x = params["tok_embed"][tokens].astype(L.cdtype(cfg))
    x = L.layer_norm(params["ln0"], x, cfg.norm_eps)
    zeros_prev = jnp.zeros((b, cfg.d_model), x.dtype)
    zero_state = jnp.zeros((b, h, n, n), jnp.float32)

    def body(hid, lp):
        a, tm_new, wkv_s = _time_mix(
            lp, L.layer_norm(lp["ln1"], hid, cfg.norm_eps),
            zeros_prev, zero_state, cfg, use_chunked=True)
        hid = hid + a
        c, cm_new = _channel_mix(
            lp, L.layer_norm(lp["ln2"], hid, cfg.norm_eps), zeros_prev, cfg)
        return hid + c, (wkv_s, tm_new.astype(jnp.float32),
                         cm_new.astype(jnp.float32))

    x, (wkv, tm_x, cm_x) = lax.scan(body, x, params["layers"])
    x = L.layer_norm(params["ln_out"], x, cfg.norm_eps)
    logits = (x[:, -1, :] @ params["lm_head"]["w"].astype(x.dtype))
    cache = {"wkv": wkv, "tm_x": tm_x, "cm_x": cm_x,
             "len": jnp.asarray(s, jnp.int32)}
    return cache, logits.astype(jnp.float32)
