"""Deterministic, index-addressable data pipeline.

Every batch is a pure function of ``(seed, step, arch)`` — no iterator
state.  Consequences that matter at cluster scale:

* resume after preemption = restore one integer (the step),
* elastic re-sharding = the same global batch materializes under any
  mesh (pjit shards it),
* no host-side shuffle buffers to checkpoint.

Two sources:
* ``synthetic``  — Zipf-distributed tokens with planted bigram structure
  (so small-model examples visibly learn),
* ``bytes``      — byte-level tokens from a text file (self-contained
  corpus mode used by examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import numpy as np

import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    source: str = "synthetic"     # synthetic | bytes
    seed: int = 1234
    path: Optional[str] = None    # bytes mode
    zipf_a: float = 1.2


def _rng_for(seed: int, step: int, stream: str):
    h = hashlib.blake2b(f"{seed}:{step}:{stream}".encode(),
                        digest_size=8).digest()
    return np.random.default_rng(int.from_bytes(h, "little"))


class Pipeline:
    def __init__(self, dcfg: DataConfig, mcfg: ModelConfig,
                 global_batch: int, seq_len: int):
        self.dcfg = dcfg
        self.mcfg = mcfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self._corpus = None
        if dcfg.source == "bytes":
            with open(dcfg.path, "rb") as f:
                self._corpus = np.frombuffer(f.read(), dtype=np.uint8)
            if len(self._corpus) < seq_len + 1:
                raise ValueError("corpus too small")

    # -- pure function of step ------------------------------------------
    def batch_at(self, step: int) -> dict:
        b, s, v = self.global_batch, self.seq_len, self.mcfg.vocab
        rng = _rng_for(self.dcfg.seed, step, "tokens")
        if self.dcfg.source == "bytes":
            starts = rng.integers(0, len(self._corpus) - s - 1, size=b)
            tok = np.stack([self._corpus[st:st + s].astype(np.int32)
                            for st in starts])
            tok = tok % v
        else:
            # Zipf body with planted bigram structure: token 2k is
            # followed by 2k+1 with high probability
            base = rng.zipf(self.dcfg.zipf_a, size=(b, s)).astype(np.int64)
            tok = (base % max(v - 2, 1)).astype(np.int32)
            follow = rng.random((b, s)) < 0.7
            shifted = np.roll(tok, 1, axis=1)
            paired = np.where((shifted % 2 == 0) & follow[:, :],
                              np.minimum(shifted + 1, v - 1), tok)
            paired[:, 0] = tok[:, 0]
            tok = paired.astype(np.int32)

        out = {"tokens": jnp.asarray(tok)}
        if self.mcfg.family == "whisper":
            frng = _rng_for(self.dcfg.seed, step, "frames")
            out["frames"] = jnp.asarray(
                frng.standard_normal(
                    (b, self.mcfg.encoder_seq, self.mcfg.d_model))
                .astype(np.float32))
        if self.mcfg.n_visual_tokens:
            vrng = _rng_for(self.dcfg.seed, step, "visual")
            out["visual"] = jnp.asarray(
                vrng.standard_normal(
                    (b, self.mcfg.n_visual_tokens, self.mcfg.d_model))
                .astype(np.float32))
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
