"""Error-feedback posit gradient compression (cross-pod DP sync).

Scheme (EF-SGD / EF21 style):
    buf    <- g + e                  (accumulate residual)
    q      <- posit_quantize(buf)    (what crosses the wire)
    e'     <- buf - dequantize(q)    (residual stays local)

With error feedback the quantization noise is *recycled*, so SGD/Adam
convergence is preserved (the bias telescopes).  ``tests/test_compression``
verifies convergence on a quadratic and exactness bounds.

The wire format is the paper's posit16/posit8; in the multi-pod train
step the quantized patterns (uint16/uint8) are what the 'pod'-axis
all-gather moves — see runtime/train_loop.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.convert import f32_to_posit, posit_to_f32
from repro.core.types import POSIT8, POSIT16, PositConfig

_CFGS = {"posit16": POSIT16, "posit8": POSIT8}


def pcfg_of(name: str) -> PositConfig:
    return _CFGS[name]


def init_error_state(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, error, name: str):
    """Returns (patterns tree, new error tree)."""
    cfg = pcfg_of(name)

    def one(g, e):
        buf = g.astype(jnp.float32) + e
        q = f32_to_posit(buf, cfg)
        e_new = buf - posit_to_f32(q, cfg)
        return q, e_new

    out = jax.tree.map(one, grads, error)
    flat, td = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    qs = jax.tree.unflatten(td, [t[0] for t in flat])
    es = jax.tree.unflatten(td, [t[1] for t in flat])
    return qs, es


def decompress(patterns, name: str):
    cfg = pcfg_of(name)
    return jax.tree.map(lambda q: posit_to_f32(q, cfg), patterns)
