"""Error-feedback posit gradient compression (cross-pod DP sync).

Scheme (EF-SGD / EF21 style):
    buf    <- g + e                  (accumulate residual)
    q      <- posit_quantize(buf)    (what crosses the wire)
    e'     <- buf - dequantize(q)    (residual stays local)

With error feedback the quantization noise is *recycled*, so SGD/Adam
convergence is preserved (the bias telescopes).  ``tests/test_compression``
verifies convergence on a quadratic and exactness bounds.

The wire format is the paper's posit16/posit8; in the multi-pod train
step the quantized patterns (uint16/uint8) are what the 'pod'-axis
all-gather moves — see runtime/train_loop.py.

Wire-format (posit-domain) reductions — ``combine_compressed``,
``mean_compressed``, ``scale_compressed`` — run on the fused Pallas
elementwise kernels (``repro.kernels.ops``): the patterns never round-trip
through f32, so a hierarchical cross-pod reduction can re-transmit its
intermediate sums in wire format with one rounding per op instead of two.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.convert import f32_to_posit, posit_to_f32
from repro.core import softposit_ref
from repro.core.types import POSIT8, POSIT16, PositConfig
from repro.kernels import ops as kops

_CFGS = {"posit16": POSIT16, "posit8": POSIT8}


def pcfg_of(name: str) -> PositConfig:
    return _CFGS[name]


def init_error_state(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, error, name: str):
    """Returns (patterns tree, new error tree)."""
    cfg = pcfg_of(name)

    def one(g, e):
        buf = g.astype(jnp.float32) + e
        q = f32_to_posit(buf, cfg)
        e_new = buf - posit_to_f32(q, cfg)
        return q, e_new

    out = jax.tree.map(one, grads, error)
    flat, td = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    qs = jax.tree.unflatten(td, [t[0] for t in flat])
    es = jax.tree.unflatten(td, [t[1] for t in flat])
    return qs, es


def decompress(patterns, name: str):
    cfg = pcfg_of(name)
    return jax.tree.map(lambda q: posit_to_f32(q, cfg), patterns)


# ---------------------------------------------------------------------------
# Posit-domain wire-format reductions (fused elementwise kernels)
# ---------------------------------------------------------------------------

def scalar_pattern(value: float, cfg: PositConfig):
    """Encode a python scalar as a 0-d posit pattern (exact RNE)."""
    return jnp.asarray(softposit_ref.from_float(float(value), cfg),
                       cfg.storage_dtype)


def combine_compressed(qa, qb, name: str, interpret: bool = True):
    """Elementwise posit add of two wire-format gradient trees.

    Single rounding per element (fused decode->add->encode); the
    dequantize->f32 add->requantize composition this replaces rounds
    twice and costs two codec passes plus an f32 temporary.
    """
    cfg = pcfg_of(name)
    return jax.tree.map(
        lambda a, b: kops.vadd(a, b, cfg, interpret=interpret), qa, qb)


def scale_compressed(q, scale: float, name: str, interpret: bool = True):
    """Scale a wire-format tree by a scalar, staying in the posit domain."""
    cfg = pcfg_of(name)
    s = scalar_pattern(scale, cfg)
    return jax.tree.map(
        lambda p: kops.vmul(p, s, cfg, interpret=interpret), q)


def mean_compressed(q_tiled, name: str, interpret: bool = True):
    """Mean over the leading (pod) axis, entirely in wire format.

    Pairwise vadd tree-reduction then one exact divide by the pod count
    (``mode='exact'`` — for power-of-two pod counts the divide is a pure
    exponent shift, so it never rounds).  The result is a pattern tree
    ready to re-transmit; ``decompress`` crosses back to f32.
    """
    cfg = pcfg_of(name)

    def one(q):
        parts = [q[i] for i in range(q.shape[0])]
        while len(parts) > 1:  # balanced tree keeps intermediate error low
            nxt = [kops.vadd(parts[i], parts[i + 1], cfg,
                             interpret=interpret)
                   for i in range(0, len(parts) - 1, 2)]
            if len(parts) % 2:
                nxt.append(parts[-1])
            parts = nxt
        count = scalar_pattern(float(q.shape[0]), cfg)
        return kops.vdiv(parts[0], count, cfg, mode="exact",
                         interpret=interpret)

    return jax.tree.map(one, q_tiled)
