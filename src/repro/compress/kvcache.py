"""Posit KV-cache quantization utilities (serving memory/bandwidth).

The models quantize/dequantize inline (see ``models/*.py``); these helpers
quantize an *existing* cache tree (e.g. after prefill in f32) and report
compression ratios for the benchmarks.

Cache *maintenance* ops (``scale_cache``, ``merge_caches``) stay entirely
in the posit domain via the fused Pallas elementwise kernels
(``repro.kernels.ops``): one decode->arith->encode pass per element
instead of the dequantize -> f32 op -> requantize round-trip, so a cache
rescale (attention-sink discounting, temperature folding) or a
speculative-decoding cache merge rounds once, not twice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.convert import f32_to_posit, posit_to_f32
from repro.core.tracing import is_tracer as _is_tracer
from repro.kernels import ops as kops
from .gradient import pcfg_of, scalar_pattern


def quantize_cache(cache, name: str):
    cfg = pcfg_of(name)

    def one(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return f32_to_posit(x.astype(jnp.float32), cfg)
        return x                                   # lengths / ints

    return jax.tree.map(one, cache)


def dequantize_cache(cache, name: str):
    cfg = pcfg_of(name)

    def one(x):
        if jnp.issubdtype(x.dtype, jnp.unsignedinteger):
            return posit_to_f32(x, cfg)
        return x

    return jax.tree.map(one, cache)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def cache_report(cache) -> dict:
    """Actual vs f32-equivalent bytes and the compression ratio.

    Posit-pattern leaves (unsigned ints) and reduced-precision float
    leaves count 4 bytes/element in the f32 baseline; integer metadata
    (``len``/``lens``/``max_len``) counts as-is.  Shape-agnostic, so it
    reports ring-buffer (window-sized) caches the same way as linear
    ones — the ratio compares storage *dtypes*, not layouts.
    """
    leaves = jax.tree.leaves(cache)
    actual = sum(x.size * x.dtype.itemsize for x in leaves)
    f32 = sum(
        x.size * 4
        if (jnp.issubdtype(x.dtype, jnp.unsignedinteger)
            or jnp.issubdtype(x.dtype, jnp.floating))
        else x.size * x.dtype.itemsize
        for x in leaves)
    return {"bytes": actual, "f32_bytes": f32,
            "ratio": f32 / max(actual, 1)}


# ---------------------------------------------------------------------------
# Posit-domain cache maintenance (fused elementwise kernels)
# ---------------------------------------------------------------------------

def _is_patterns(x) -> bool:
    return jnp.issubdtype(x.dtype, jnp.unsignedinteger)


def scale_cache(cache, factor: float, name: str, interpret: bool = True):
    """Multiply every quantized leaf by ``factor`` in the posit domain.

    Non-pattern leaves (lengths, positions) pass through untouched.
    """
    cfg = pcfg_of(name)
    s = scalar_pattern(factor, cfg)

    def one(x):
        if _is_patterns(x):
            return kops.vmul(x, s, cfg, interpret=interpret)
        return x

    return jax.tree.map(one, cache)


def merge_caches(cache_a, cache_b, name: str, weight_a: float = 0.5,
                 interpret: bool = True):
    """Blend two quantized caches: ``wa * a + (1 - wa) * b``, fused.

    Three posit-domain ops (two vmul, one vadd) — each exactly rounded —
    versus two full dequantize passes, three f32 ops, and a requantize.

    Non-pattern leaves (lengths, positions) must agree between the two
    caches — blending the K/V contents of caches with different metadata
    would silently produce an inconsistent cache, so that is an error.
    The guard is trace-safe: shape/dtype mismatches raise even under
    ``jax.jit`` (they are static), while the value-equality check runs
    only on concrete (non-tracer) leaves — a jitted merge trusts the
    caller's metadata values, as a host-side guard cannot inspect
    traced data without aborting the trace.
    """
    cfg = pcfg_of(name)
    wa = scalar_pattern(weight_a, cfg)
    wb = scalar_pattern(1.0 - float(weight_a), cfg)

    def one(a, b):
        if _is_patterns(a) and _is_patterns(b):
            return kops.vadd(kops.vmul(a, wa, cfg, interpret=interpret),
                             kops.vmul(b, wb, cfg, interpret=interpret),
                             cfg, interpret=interpret)
        if a.shape != b.shape or a.dtype != b.dtype:
            raise ValueError(
                "merge_caches: non-pattern (metadata) leaves differ "
                f"between caches: {a.shape}/{a.dtype} vs "
                f"{b.shape}/{b.dtype}; refusing to blend K/V contents "
                "of inconsistent caches")
        if (not _is_tracer(a) and not _is_tracer(b)
                and not bool(jnp.all(a == b))):
            raise ValueError(
                "merge_caches: non-pattern (metadata) leaves differ "
                f"between caches (shape {a.shape}); refusing to blend "
                "K/V contents of inconsistent caches")
        return a

    return jax.tree.map(one, cache_a, cache_b)
