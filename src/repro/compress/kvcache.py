"""Posit KV-cache quantization utilities (serving memory/bandwidth).

The models quantize/dequantize inline (see ``models/*.py``); these helpers
quantize an *existing* cache tree (e.g. after prefill in f32) and report
compression ratios for the benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.convert import f32_to_posit, posit_to_f32
from .gradient import pcfg_of


def quantize_cache(cache, name: str):
    cfg = pcfg_of(name)

    def one(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return f32_to_posit(x.astype(jnp.float32), cfg)
        return x                                   # lengths / ints

    return jax.tree.map(one, cache)


def dequantize_cache(cache, name: str):
    cfg = pcfg_of(name)

    def one(x):
        if jnp.issubdtype(x.dtype, jnp.unsignedinteger):
            return posit_to_f32(x, cfg)
        return x

    return jax.tree.map(one, cache)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
