"""Posit KV-cache quantization utilities (serving memory/bandwidth).

The models quantize/dequantize inline (see ``models/*.py``); these helpers
quantize an *existing* cache tree (e.g. after prefill in f32) and report
compression ratios for the benchmarks.

Cache *maintenance* ops (``scale_cache``, ``merge_caches``) stay entirely
in the posit domain via the fused Pallas elementwise kernels
(``repro.kernels.ops``): one decode->arith->encode pass per element
instead of the dequantize -> f32 op -> requantize round-trip, so a cache
rescale (attention-sink discounting, temperature folding) or a
speculative-decoding cache merge rounds once, not twice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax import lax

from repro.core.convert import f32_to_posit, posit_to_f32
from repro.core.tracing import is_tracer as _is_tracer
from repro.kernels import ops as kops
from .gradient import pcfg_of, scalar_pattern


def quantize_cache(cache, name: str):
    cfg = pcfg_of(name)

    def one(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return f32_to_posit(x.astype(jnp.float32), cfg)
        return x                                   # lengths / ints

    return jax.tree.map(one, cache)


def dequantize_cache(cache, name: str):
    cfg = pcfg_of(name)

    def one(x):
        if jnp.issubdtype(x.dtype, jnp.unsignedinteger):
            return posit_to_f32(x, cfg)
        return x

    return jax.tree.map(one, cache)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def cache_report(cache) -> dict:
    """Actual vs f32-equivalent bytes and the compression ratio.

    Posit-pattern leaves (unsigned ints) and reduced-precision float
    leaves count 4 bytes/element in the f32 baseline; integer metadata
    (``len``/``lens``/``max_len``) counts as-is.  Shape-agnostic, so it
    reports ring-buffer (window-sized) caches the same way as linear
    ones — the ratio compares storage *dtypes*, not layouts.
    """
    leaves = jax.tree.leaves(cache)
    actual = sum(x.size * x.dtype.itemsize for x in leaves)
    f32 = sum(
        x.size * 4
        if (jnp.issubdtype(x.dtype, jnp.unsignedinteger)
            or jnp.issubdtype(x.dtype, jnp.floating))
        else x.size * x.dtype.itemsize
        for x in leaves)
    return {"bytes": actual, "f32_bytes": f32,
            "ratio": f32 / max(actual, 1)}


# ---------------------------------------------------------------------------
# Per-slot cache surgery (continuous-batching scheduler support)
#
# Engine-shaped caches carry metadata leaves ``len`` (scalar padded-write
# frontier), ``lens`` ((B,) per-row valid counts) and ``max_len``.  The
# scheduler treats the batch dimension as a SLOT POOL: retired rows are
# wiped (``reset_slots``), the shared frontier is moved so freed headroom
# is reclaimed or a long admitted prompt fits (``compact``), and a
# freshly prefilled single-prompt cache is grafted into a free row
# (``adopt_row``).  All three are jit-safe (shifts/rows may be traced) and
# layout-agnostic: time-axis leaves roll circularly, which is exact for
# linear caches (stale slots stay masked by ``lens``) and IS the frontier
# relabelling for ring buffers (slot = pos % T).
# ---------------------------------------------------------------------------

# Leaves with a (stack, batch, time, ...) layout that must move with the
# write frontier; everything else either has no time axis (``ssm`` state,
# metadata) or is not cache content.
_TIME_LEAVES = frozenset(
    {"k", "v", "c_kv", "k_rope", "k_swa", "v_swa", "k_glb", "v_glb"})
# Per-row state without a time axis (cleared on reset, copied on adopt).
_ROW_LEAVES = frozenset({"ssm"})


def reset_slots(cache, rows):
    """Retire the given batch rows: ``lens -> 0`` and their cache content
    zeroed.  ``rows``: (B,) bool, True = free this slot.

    The zeroing is hygiene (attention already masks retired rows via
    ``lens``); the load-bearing part is the metadata reset, which lets
    ``compact`` reclaim the headroom the retired rows were pinning.
    """
    from repro.models import layers as L

    rows = jnp.asarray(rows, bool)
    out = dict(cache)
    for key, leaf in cache.items():
        if key in _TIME_LEAVES or key in _ROW_LEAVES:
            out[key] = L.reset_cache_rows(leaf, rows)
    out["lens"] = jnp.where(rows, 0, jnp.asarray(cache["lens"], jnp.int32))
    return out


def compact(cache, target_len=None):
    """Move the shared write frontier to ``target_len`` (default: the
    tightest frontier, ``max(lens)``), rolling every time-axis leaf so
    row content still ends at the frontier.

    Shrinking (the common case after retirements) reclaims headroom so
    decode chunks keep fitting in ``max_len``; growing makes room for an
    admitted prompt longer than the current frontier.  ``lens`` and
    ``max_len`` are unchanged — per-row content is only relabelled.
    """
    from repro.models import layers as L

    cur = jnp.asarray(cache["len"], jnp.int32)
    target = (jnp.max(jnp.asarray(cache["lens"], jnp.int32))
              if target_len is None else jnp.asarray(target_len, jnp.int32))
    if not _is_tracer(target) and not _is_tracer(cache["max_len"]):
        if int(target) > int(cache["max_len"]):
            raise ValueError(
                f"compact: target frontier {int(target)} exceeds cache "
                f"max_len {int(cache['max_len'])}")
    shift = target - cur
    out = dict(cache)
    for key, leaf in cache.items():
        if key in _TIME_LEAVES:
            out[key] = L.roll_cache_time(leaf, shift)
    out["len"] = target
    return out


def adopt_row(cache, row_cache, row):
    """Graft a batch-1 prefilled cache into slot ``row`` of a pool cache.

    ``row_cache`` must come from the same model/config (leaf shapes match
    except batch = 1) with frontier ``row_cache['len'] <= cache['len']``:
    its content is rolled up so the prompt ends at the pool's shared
    frontier (per-row RoPE positions are content-relative, so relabelling
    padded slots is free), then scattered into batch row ``row``; the
    row's ``lens`` entry takes the prompt length.
    """
    cur = cache["len"]
    src = row_cache["len"]
    if not _is_tracer(cur) and not _is_tracer(src) \
            and int(src) > int(cur):
        raise ValueError(
            f"adopt_row: admitted prompt frontier {int(src)} exceeds the "
            f"pool frontier {int(cur)}; compact(cache, target_len="
            f"{int(src)}) first")
    from repro.models import layers as L

    shift = jnp.asarray(cur, jnp.int32) - jnp.asarray(src, jnp.int32)
    row = jnp.asarray(row, jnp.int32)
    out = dict(cache)
    for key, leaf in cache.items():
        if key in _TIME_LEAVES and key in row_cache:
            upd = L.roll_cache_time(row_cache[key], shift)
            starts = (jnp.zeros((), jnp.int32), row) + \
                tuple(jnp.zeros((), jnp.int32) for _ in range(leaf.ndim - 2))
            out[key] = lax.dynamic_update_slice(leaf, upd, starts)
        elif key in _ROW_LEAVES and key in row_cache:
            starts = (jnp.zeros((), jnp.int32), row) + \
                tuple(jnp.zeros((), jnp.int32) for _ in range(leaf.ndim - 2))
            out[key] = lax.dynamic_update_slice(leaf, row_cache[key], starts)
    out["lens"] = lax.dynamic_update_slice(
        jnp.asarray(cache["lens"], jnp.int32),
        jnp.asarray(row_cache["lens"], jnp.int32), (row,))
    return out


# ---------------------------------------------------------------------------
# Posit-domain cache maintenance (fused elementwise kernels)
# ---------------------------------------------------------------------------

def _is_patterns(x) -> bool:
    return jnp.issubdtype(x.dtype, jnp.unsignedinteger)


def scale_cache(cache, factor: float, name: str, interpret: bool = True):
    """Multiply every quantized leaf by ``factor`` in the posit domain.

    Non-pattern leaves (lengths, positions) pass through untouched.
    """
    cfg = pcfg_of(name)
    s = scalar_pattern(factor, cfg)

    def one(x):
        if _is_patterns(x):
            return kops.vmul(x, s, cfg, interpret=interpret)
        return x

    return jax.tree.map(one, cache)


def merge_caches(cache_a, cache_b, name: str, weight_a: float = 0.5,
                 interpret: bool = True):
    """Blend two quantized caches: ``wa * a + (1 - wa) * b``, fused.

    Three posit-domain ops (two vmul, one vadd) — each exactly rounded —
    versus two full dequantize passes, three f32 ops, and a requantize.

    Non-pattern leaves (lengths, positions) must agree between the two
    caches — blending the K/V contents of caches with different metadata
    would silently produce an inconsistent cache, so that is an error.
    The guard is trace-safe: shape/dtype mismatches raise even under
    ``jax.jit`` (they are static), while the value-equality check runs
    only on concrete (non-tracer) leaves — a jitted merge trusts the
    caller's metadata values, as a host-side guard cannot inspect
    traced data without aborting the trace.
    """
    cfg = pcfg_of(name)
    wa = scalar_pattern(weight_a, cfg)
    wb = scalar_pattern(1.0 - float(weight_a), cfg)

    def one(a, b):
        if _is_patterns(a) and _is_patterns(b):
            return kops.vadd(kops.vmul(a, wa, cfg, interpret=interpret),
                             kops.vmul(b, wb, cfg, interpret=interpret),
                             cfg, interpret=interpret)
        if a.shape != b.shape or a.dtype != b.dtype:
            raise ValueError(
                "merge_caches: non-pattern (metadata) leaves differ "
                f"between caches: {a.shape}/{a.dtype} vs "
                f"{b.shape}/{b.dtype}; refusing to blend K/V contents "
                "of inconsistent caches")
        if (not _is_tracer(a) and not _is_tracer(b)
                and not bool(jnp.all(a == b))):
            raise ValueError(
                "merge_caches: non-pattern (metadata) leaves differ "
                f"between caches (shape {a.shape}); refusing to blend "
                "K/V contents of inconsistent caches")
        return a

    return jax.tree.map(one, cache_a, cache_b)
