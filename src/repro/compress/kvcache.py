"""Posit KV-cache quantization utilities (serving memory/bandwidth).

The models quantize/dequantize inline (see ``models/*.py``); these helpers
quantize an *existing* cache tree (e.g. after prefill in f32) and report
compression ratios for the benchmarks.

Cache *maintenance* ops (``scale_cache``, ``merge_caches``) stay entirely
in the posit domain via the fused Pallas elementwise kernels
(``repro.kernels.ops``): one decode->arith->encode pass per element
instead of the dequantize -> f32 op -> requantize round-trip, so a cache
rescale (attention-sink discounting, temperature folding) or a
speculative-decoding cache merge rounds once, not twice.

This module also owns the serving cache MEMORY model: the per-slot
surgery ops for the linear/ring layouts (``reset_slots`` / ``compact`` /
``adopt_row``) and the paged layout's ``BlockPool`` free list plus
block-table surgery (``paged_adopt_row`` / ``paged_release_rows``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax import lax, tree_util

from repro.core.convert import f32_to_posit, posit_to_f32
from repro.core.tracing import is_tracer as _is_tracer
from repro.kernels import ops as kops
from .gradient import pcfg_of, scalar_pattern


# ---------------------------------------------------------------------------
# Explicit cache-leaf schema (pattern vs metadata tagging)
#
# Caches are plain dict pytrees, so the leaf NAME is the tag: every cache
# content leaf (K/V, latents, recurrent state — the things a posit codec
# may have quantized to unsigned patterns) is registered in
# ``CONTENT_LEAVES``; bookkeeping (frontiers, per-row lengths, paged block
# tables) in ``META_LEAVES``.  The old heuristic sniffed ``unsignedinteger``
# dtypes, which misclassifies any unsigned bookkeeping leaf (a uint block
# table would have been "scaled" as posit patterns) and cannot distinguish
# an f32 cache's content from metadata.  Unknown unsigned leaves now raise
# instead of guessing.
# ---------------------------------------------------------------------------

# Time-axis / row-state content (also the cache-surgery move set below).
_TIME_LEAVES = frozenset(
    {"k", "v", "c_kv", "k_rope", "k_swa", "v_swa", "k_glb", "v_glb"})
# Per-row state without a time axis (cleared on reset, copied on adopt).
_ROW_LEAVES = frozenset({"ssm"})
# All content: time leaves + row state + whisper cross-attention KV +
# rwkv recurrent state.
CONTENT_LEAVES = _TIME_LEAVES | _ROW_LEAVES | frozenset(
    {"ck", "cv", "wkv", "tm_x", "cm_x"})
META_LEAVES = frozenset(
    {"len", "lens", "max_len", "length", "block_tables"})


def _leaf_key(path):
    for entry in reversed(path):
        if isinstance(entry, tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, tree_util.GetAttrKey):
            return str(entry.name)
    return None


def _leaf_is_patterns(path, x) -> bool:
    key = _leaf_key(path)
    if key is None:                 # bare array / unkeyed tree: dtype only
        return jnp.issubdtype(x.dtype, jnp.unsignedinteger)
    if key in CONTENT_LEAVES:
        return jnp.issubdtype(x.dtype, jnp.unsignedinteger)
    if key in META_LEAVES:
        return False
    if jnp.issubdtype(x.dtype, jnp.unsignedinteger):
        raise ValueError(
            f"unknown unsigned cache leaf {key!r}: register it in "
            "kvcache.CONTENT_LEAVES (posit patterns) or "
            "kvcache.META_LEAVES (bookkeeping); refusing to guess from "
            "the dtype")
    return False


def _leaf_is_content(path, x) -> bool:
    key = _leaf_key(path)
    if key is None:
        return (jnp.issubdtype(x.dtype, jnp.unsignedinteger)
                or jnp.issubdtype(x.dtype, jnp.floating))
    return key in CONTENT_LEAVES


def quantize_cache(cache, name: str):
    cfg = pcfg_of(name)

    def one(path, x):
        if _leaf_is_content(path, x) and \
                jnp.issubdtype(x.dtype, jnp.floating):
            return f32_to_posit(x.astype(jnp.float32), cfg)
        key = _leaf_key(path)
        if key is not None and key not in META_LEAVES and \
                key not in CONTENT_LEAVES and \
                jnp.issubdtype(x.dtype, jnp.floating):
            raise ValueError(
                f"unknown float cache leaf {key!r}: register it in "
                "kvcache.CONTENT_LEAVES (quantizable content) or "
                "kvcache.META_LEAVES (bookkeeping); refusing to "
                "silently skip it")
        return x                                   # lengths / ints

    return tree_util.tree_map_with_path(one, cache)


def dequantize_cache(cache, name: str):
    cfg = pcfg_of(name)

    def one(path, x):
        if _leaf_is_patterns(path, x):
            return posit_to_f32(x, cfg)
        return x

    return tree_util.tree_map_with_path(one, cache)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def _shard_bytes(x) -> int:
    """Bytes of ``x`` resident on one device: the per-shard shape when
    the leaf carries a (Named)Sharding, the full size otherwise (plain
    numpy leaves, abstract shapes)."""
    sharding = getattr(x, "sharding", None)
    if sharding is None or not hasattr(sharding, "shard_shape"):
        return x.size * x.dtype.itemsize
    shape = sharding.shard_shape(x.shape)
    n = 1
    for s in shape:
        n *= int(s)
    return n * x.dtype.itemsize


def cache_report(cache, pool=None) -> dict:
    """Actual vs f32-equivalent bytes and the compression ratio.

    Content leaves (posit patterns or reduced-precision floats, per the
    explicit ``CONTENT_LEAVES`` schema) count 4 bytes/element in the f32
    baseline; bookkeeping (``len``/``lens``/``max_len``/``block_tables``)
    counts as-is.  Shape-agnostic, so it reports ring-buffer
    (window-sized) and paged (block-arena) caches the same way as linear
    ones — the ratio compares storage *dtypes*, not layouts, while
    ``bytes`` reflects the layout's actual footprint (a paged arena
    sized below ``slots x max_len`` reports correspondingly fewer
    bytes).

    ``pool`` (a :class:`BlockPool`) extends the report for paged caches
    with the PHYSICAL vs LOGICAL block split prefix sharing creates:
    ``physical_blocks`` are resident arena blocks, ``logical_blocks``
    sum the references to them (what a non-sharing pool would hold),
    and the peaks record the trace high-water marks.  With no sharing
    the two columns are equal; their gap is the deduplication win.

    ``per_device_bytes`` is the cache's footprint on ONE device, read
    off each leaf's actual sharding (``shard_shape``): equal to
    ``bytes`` on a single device or a replicated placement, and
    ``arena/model_parallel`` + replicated metadata when the arena is
    head-sharded over a mesh — the number the sharded serving
    benchmark asserts drops ~linearly with the model-parallel degree.
    """
    leaves = tree_util.tree_leaves_with_path(cache)
    actual = sum(x.size * x.dtype.itemsize for _, x in leaves)
    f32 = sum(
        x.size * 4 if _leaf_is_content(p, x) else x.size * x.dtype.itemsize
        for p, x in leaves)
    out = {"bytes": actual, "f32_bytes": f32,
           "ratio": f32 / max(actual, 1),
           "per_device_bytes": sum(_shard_bytes(x) for _, x in leaves)}
    if pool is not None:
        out.update(
            physical_blocks=pool.in_use,
            logical_blocks=pool.logical_in_use,
            peak_physical_blocks=pool.peak_in_use,
            peak_logical_blocks=pool.peak_logical)
    return out


# ---------------------------------------------------------------------------
# Per-slot cache surgery (continuous-batching scheduler support)
#
# Engine-shaped caches carry metadata leaves ``len`` (scalar padded-write
# frontier), ``lens`` ((B,) per-row valid counts) and ``max_len``.  The
# scheduler treats the batch dimension as a SLOT POOL: retired rows are
# wiped (``reset_slots``), the shared frontier is moved so freed headroom
# is reclaimed or a long admitted prompt fits (``compact``), and a
# freshly prefilled single-prompt cache is grafted into a free row
# (``adopt_row``).  All three are jit-safe (shifts/rows may be traced) and
# layout-agnostic: time-axis leaves roll circularly, which is exact for
# linear caches (stale slots stay masked by ``lens``) and IS the frontier
# relabelling for ring buffers (slot = pos % T).
# ---------------------------------------------------------------------------

# ``_TIME_LEAVES`` (defined with the leaf schema above) is the move set:
# leaves with a (stack, batch, time, ...) layout that must travel with the
# write frontier; ``_ROW_LEAVES`` is per-row state without a time axis.


def is_paged(cache) -> bool:
    """True for block-table (paged) caches; their batch rows address the
    shared block arena through per-row tables, so the linear/ring
    surgery ops below do not apply (see the paged section)."""
    return isinstance(cache, dict) and "block_tables" in cache


def _reject_paged(cache, what: str):
    if is_paged(cache):
        raise ValueError(
            f"{what}: paged (block-table) caches have no shared linear "
            "frontier to move; use paged_adopt_row / paged_release_rows "
            "and the BlockPool instead")


def reset_slots(cache, rows):
    """Retire the given batch rows: ``lens -> 0`` and their cache content
    zeroed.  ``rows``: (B,) bool, True = free this slot.

    The zeroing is hygiene (attention already masks retired rows via
    ``lens``); the load-bearing part is the metadata reset, which lets
    ``compact`` reclaim the headroom the retired rows were pinning.
    """
    from repro.models import layers as L

    _reject_paged(cache, "reset_slots")
    rows = jnp.asarray(rows, bool)
    out = dict(cache)
    for key, leaf in cache.items():
        if key in _TIME_LEAVES or key in _ROW_LEAVES:
            out[key] = L.reset_cache_rows(leaf, rows)
    out["lens"] = jnp.where(rows, 0, jnp.asarray(cache["lens"], jnp.int32))
    return out


def compact(cache, target_len=None):
    """Move the shared write frontier to ``target_len`` (default: the
    tightest frontier, ``max(lens)``), rolling every time-axis leaf so
    row content still ends at the frontier.

    Shrinking (the common case after retirements) reclaims headroom so
    decode chunks keep fitting in ``max_len``; growing makes room for an
    admitted prompt longer than the current frontier.  ``lens`` and
    ``max_len`` are unchanged — per-row content is only relabelled.
    """
    from repro.models import layers as L

    _reject_paged(cache, "compact")
    cur = jnp.asarray(cache["len"], jnp.int32)
    target = (jnp.max(jnp.asarray(cache["lens"], jnp.int32))
              if target_len is None else jnp.asarray(target_len, jnp.int32))
    if not _is_tracer(target) and not _is_tracer(cache["max_len"]):
        if int(target) > int(cache["max_len"]):
            raise ValueError(
                f"compact: target frontier {int(target)} exceeds cache "
                f"max_len {int(cache['max_len'])}")
    shift = target - cur
    out = dict(cache)
    for key, leaf in cache.items():
        if key in _TIME_LEAVES:
            out[key] = L.roll_cache_time(leaf, shift)
    out["len"] = target
    return out


def adopt_row(cache, row_cache, row):
    """Graft a batch-1 prefilled cache into slot ``row`` of a pool cache.

    ``row_cache`` must come from the same model/config (leaf shapes match
    except batch = 1) with frontier ``row_cache['len'] <= cache['len']``:
    its content is rolled up so the prompt ends at the pool's shared
    frontier (per-row RoPE positions are content-relative, so relabelling
    padded slots is free), then scattered into batch row ``row``; the
    row's ``lens`` entry takes the prompt length.
    """
    _reject_paged(cache, "adopt_row")
    cur = cache["len"]
    src = row_cache["len"]
    if not _is_tracer(cur) and not _is_tracer(src) \
            and int(src) > int(cur):
        raise ValueError(
            f"adopt_row: admitted prompt frontier {int(src)} exceeds the "
            f"pool frontier {int(cur)}; compact(cache, target_len="
            f"{int(src)}) first")
    from repro.models import layers as L

    shift = jnp.asarray(cur, jnp.int32) - jnp.asarray(src, jnp.int32)
    row = jnp.asarray(row, jnp.int32)
    out = dict(cache)
    # Clamping is impossible in these grafts, so the guarded helpers are
    # not needed (and would not fit: multi-axis starts, row axis 1): every
    # start is 0 except `row`, each update spans the full extent of its
    # axis (so start 0 never clamps), and `row` comes from the scheduler's
    # slot pool (0 <= row < n_slots); a bad frontier is rejected by the
    # eager ValueError above before any device write.
    for key, leaf in cache.items():
        if key in _TIME_LEAVES and key in row_cache:
            upd = L.roll_cache_time(row_cache[key], shift)
            starts = (jnp.zeros((), jnp.int32), row) + \
                tuple(jnp.zeros((), jnp.int32) for _ in range(leaf.ndim - 2))
            out[key] = lax.dynamic_update_slice(leaf, upd, starts)  # positcheck: disable=PVU001
        elif key in _ROW_LEAVES and key in row_cache:
            starts = (jnp.zeros((), jnp.int32), row) + \
                tuple(jnp.zeros((), jnp.int32) for _ in range(leaf.ndim - 2))
            out[key] = lax.dynamic_update_slice(leaf, row_cache[key], starts)  # positcheck: disable=PVU001
    out["lens"] = lax.dynamic_update_slice(  # positcheck: disable=PVU001 (int32 metadata row, same bound)
        jnp.asarray(cache["lens"], jnp.int32),
        jnp.asarray(row_cache["lens"], jnp.int32), (row,))
    return out


# ---------------------------------------------------------------------------
# Posit-domain cache maintenance (fused elementwise kernels)
# ---------------------------------------------------------------------------

def scale_cache(cache, factor: float, name: str, interpret: bool = True):
    """Multiply every quantized leaf by ``factor`` in the posit domain.

    Pattern leaves are identified by the explicit ``CONTENT_LEAVES``
    schema (not dtype sniffing); metadata (lengths, positions, block
    tables) passes through untouched.
    """
    cfg = pcfg_of(name)
    s = scalar_pattern(factor, cfg)

    def one(path, x):
        if _leaf_is_patterns(path, x):
            return kops.vmul(x, s, cfg, interpret=interpret)
        return x

    return tree_util.tree_map_with_path(one, cache)


def merge_caches(cache_a, cache_b, name: str, weight_a: float = 0.5,
                 interpret: bool = True):
    """Blend two quantized caches: ``wa * a + (1 - wa) * b``, fused.

    Three posit-domain ops (two vmul, one vadd) — each exactly rounded —
    versus two full dequantize passes, three f32 ops, and a requantize.

    Non-pattern leaves (lengths, positions) must agree between the two
    caches — blending the K/V contents of caches with different metadata
    would silently produce an inconsistent cache, so that is an error.
    The guard is trace-safe: shape/dtype mismatches raise even under
    ``jax.jit`` (they are static), while the value-equality check runs
    only on concrete (non-tracer) leaves — a jitted merge trusts the
    caller's metadata values, as a host-side guard cannot inspect
    traced data without aborting the trace.
    """
    cfg = pcfg_of(name)
    wa = scalar_pattern(weight_a, cfg)
    wb = scalar_pattern(1.0 - float(weight_a), cfg)

    def one(path, a, b):
        if _leaf_is_patterns(path, a) and _leaf_is_patterns(path, b):
            return kops.vadd(kops.vmul(a, wa, cfg, interpret=interpret),
                             kops.vmul(b, wb, cfg, interpret=interpret),
                             cfg, interpret=interpret)
        if a.shape != b.shape or a.dtype != b.dtype:
            raise ValueError(
                "merge_caches: non-pattern (metadata) leaves differ "
                f"between caches: {a.shape}/{a.dtype} vs "
                f"{b.shape}/{b.dtype}; refusing to blend K/V contents "
                "of inconsistent caches")
        if (not _is_tracer(a) and not _is_tracer(b)
                and not bool(jnp.all(a == b))):
            raise ValueError(
                "merge_caches: non-pattern (metadata) leaves differ "
                f"between caches (shape {a.shape}); refusing to blend "
                "K/V contents of inconsistent caches")
        return a

    return tree_util.tree_map_with_path(one, cache_a, cache_b)


# ---------------------------------------------------------------------------
# Paged KV cache: a BlockPool free-list over a global arena of fixed-size
# posit-pattern blocks, plus per-sequence block tables.
#
# Layout (see ``models/transformer.py`` for the model-side lanes):
#   * arena content leaves are (L, n_blocks, block_size, ...) — one global
#     pool of blocks shared by every batch row;
#   * ``block_tables`` is (B, W) int32: row b's logical block i lives in
#     physical arena block ``block_tables[b, i]``; unassigned entries hold
#     the OUT-OF-RANGE sentinel ``n_blocks`` so a write through them is
#     DROPPED by the scatter (the paged re-expression of the engine's
#     never-clamp guarantee) and a gather through them clamps into masked
#     garbage;
#   * addressing is ROW-LOCAL: row b's token p occupies logical block
#     ``p // block_size`` at offset ``p % block_size``.  There is no
#     shared padded frontier (no ``len`` leaf) and therefore nothing to
#     ``compact`` — admission just packs a prompt's KV into freshly
#     allocated blocks, and retirement frees them back to the pool.
#
# The ``BlockPool`` itself is HOST state (a free list), like the
# scheduler's frontier mirror: block ids only cross to the device inside
# ``block_tables``.
# ---------------------------------------------------------------------------


class BlockSanitizerError(ValueError):
    """Arena-sanitizer violation: double free, use-after-free, a write
    into a shared (refcount > 1) block that skipped copy-on-write, or a
    wild block id.  Subclasses ``ValueError`` so callers guarding the
    plain allocator errors keep working."""


class BlockPool:
    """Host-side refcounted allocator over ``n_blocks`` arena block ids.

    Contract (pinned by ``tests/test_paged.py`` and
    ``tests/test_prefix.py``):

    * ``alloc(n)`` hands out ``n`` distinct PHYSICALLY free blocks, each
      with refcount 1.  A block is never handed out twice while any
      reference to it is live.
    * ``share(ids)`` increments refcounts — how a request (or the
      scheduler's :class:`PrefixIndex`) borrows blocks another owner
      packed.  Sharing never moves or copies data; it only pins the
      block against physical reclaim.
    * ``free(ids)`` / ``release(ids)`` (aliases) DECREMENT refcounts;
      the block returns to the free list only when its refcount reaches
      zero.  Dropping a reference that is not held raises (the double
      free guard).
    * ``in_use`` counts PHYSICAL resident blocks;
      ``logical_in_use`` counts references (what a non-sharing pool
      would have resident).  ``logical_in_use - in_use`` is therefore
      the blocks deduplication is currently saving.
    * ``peak_in_use`` / ``peak_logical`` are the corresponding
      high-water marks (capacity planning / the benchmark's
      physical-vs-logical report).

    Sanitizer mode (``BlockPool(n, sanitize=True)``, opt-in): misuse of
    freed ids raises :class:`BlockSanitizerError` with a use-after-free
    vs double-free diagnosis, and the ``check_write``/``check_read``
    gates let the scheduler validate every block a device scatter/gather
    is about to touch — including the COW invariant (no write into a
    refcount > 1 block).  The engine pairs this with device-side
    poisoning of reclaimed blocks (``layers.paged_poison_blocks``) so a
    stale table entry that slips past the host checks detonates the
    logits instead of silently serving freed KV.
    """

    def __init__(self, n_blocks: int, *, sanitize: bool = False):
        if n_blocks < 1:
            raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
        self.n_blocks = int(n_blocks)
        self._free = list(range(self.n_blocks - 1, -1, -1))  # pop() -> asc
        self._ref: dict = {}            # block id -> refcount (>= 1)
        self.peak_in_use = 0
        self.peak_logical = 0
        # Sanitizer mode (opt-in, see class docstring): track which ids
        # have been freed and not since reallocated so misuse reports can
        # tell use-after-free from a wild/foreign id, and upgrade the
        # guards to ``check_write``/``check_read`` entry points callers
        # (the scheduler) invoke before touching the device arena.
        self.sanitize = bool(sanitize)
        self._freed: set = set()        # freed and not yet reallocated
        self.n_sanitizer_checks = 0

    @property
    def n_free(self) -> int:
        """Physically free blocks (refcount zero)."""
        return len(self._free)

    @property
    def in_use(self) -> int:
        """Physically resident blocks (refcount >= 1)."""
        return len(self._ref)

    @property
    def logical_in_use(self) -> int:
        """Sum of refcounts: the blocks a non-sharing pool would hold."""
        return sum(self._ref.values())

    def refcount(self, block_id: int) -> int:
        """Live references to ``block_id`` (0 = physically free)."""
        return self._ref.get(int(block_id), 0)

    def _note_peaks(self):
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        self.peak_logical = max(self.peak_logical, self.logical_in_use)

    def alloc(self, n: int) -> list:
        """Take ``n`` physically free blocks, refcount 1 each."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            raise MemoryError(
                f"BlockPool exhausted: {n} blocks requested, "
                f"{len(self._free)} free of {self.n_blocks}")
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self._ref[i] = 1
            self._freed.discard(i)
        self._note_peaks()
        return ids

    def share(self, ids) -> None:
        """Increment refcounts: borrow already-resident blocks."""
        ids = [int(i) for i in ids]
        for i in ids:
            if i not in self._ref:
                if self.sanitize and i in self._freed:
                    raise BlockSanitizerError(
                        f"use-after-free: BlockPool.share of block {i}, "
                        "which is not allocated (freed earlier and not "
                        "reallocated)")
                raise ValueError(
                    f"BlockPool.share: block {i} is not allocated; only "
                    "resident blocks can be shared")
        for i in ids:
            self._ref[i] += 1
        self._note_peaks()

    def free(self, ids) -> list:
        """Drop one reference per id; physical reclaim at refcount zero.

        Returns the ids physically reclaimed by THIS call (refcount hit
        zero) — the sanitizer poisons exactly those arena blocks.
        """
        ids = [int(i) for i in ids]
        for i in ids:
            if i not in self._ref:
                if self.sanitize and i in self._freed:
                    raise BlockSanitizerError(
                        f"double free: block {i} is not allocated "
                        "(already freed and not reallocated)")
                raise ValueError(
                    f"BlockPool.free: block {i} is not allocated "
                    "(double free or foreign id)")
        reclaimed = []
        for i in ids:
            self._ref[i] -= 1
            if self._ref[i] == 0:
                del self._ref[i]
                self._free.append(i)
                self._freed.add(i)
                reclaimed.append(i)
        return reclaimed

    # ``release`` is the sharing-side name for the same decref.
    release = free

    def allocated_ids(self) -> list:
        """Sorted ids of physically resident blocks (refcount >= 1)."""
        return sorted(self._ref)

    def check_write(self, ids) -> None:
        """Sanitizer gate for an imminent arena write into ``ids``.

        Raises :class:`BlockSanitizerError` on a write into a block that
        is not allocated (use-after-free / wild write) or whose refcount
        is > 1 — a shared block being written without copy-on-write,
        which would silently corrupt every other owner's KV.
        """
        self.n_sanitizer_checks += 1
        for i in (int(i) for i in ids):
            rc = self._ref.get(i)
            if rc is None:
                kind = ("use-after-free" if i in self._freed
                        else "unallocated (wild)")
                raise BlockSanitizerError(
                    f"{kind} write: block {i} is not allocated")
            if rc > 1:
                raise BlockSanitizerError(
                    f"COW violation: write into block {i} with refcount "
                    f"{rc} — shared blocks must be copied "
                    "(copy-on-write) before the first write")

    def check_read(self, ids) -> None:
        """Sanitizer gate for reads: every id must be resident."""
        self.n_sanitizer_checks += 1
        for i in (int(i) for i in ids):
            if i not in self._ref:
                kind = ("use-after-free" if i in self._freed
                        else "unallocated (wild)")
                raise BlockSanitizerError(
                    f"{kind} read: block {i} is not allocated")


def prefix_block_hashes(tokens, block_size: int) -> list:
    """Rolling content hash of each FULL block of a token sequence.

    ``out[i]`` identifies the (i+1)-block-long prefix ``tokens[:(i+1)*bs]``
    — each hash chains the previous one, so two sequences share
    ``out[i]`` iff they agree on every token up to and including block
    ``i``.  Partial trailing blocks get no hash: only blocks whose
    content can never grow are content-addressable (a half-filled block
    would change identity on the next decode write).
    """
    bs = int(block_size)
    toks = [int(t) for t in tokens]
    out = []
    h = None
    for i in range(len(toks) // bs):
        h = hash((h,) + tuple(toks[i * bs:(i + 1) * bs]))
        out.append(h)
    return out


class PrefixIndex:
    """Content-addressed map: rolling block hash -> resident arena block.

    The scheduler registers every fully-written prompt block here and
    holds ONE pool reference per registered block, so cached prefixes
    stay resident after their owner retires.  Entries are kept in LRU
    order; a block whose only remaining reference is the index's
    (``pool.refcount == 1``) is *evictable* — the scheduler reclaims
    those, oldest first, when admission needs physical blocks.
    First-writer-wins: registering a hash that is already mapped is a
    no-op (the resident copy keeps serving matches).
    """

    def __init__(self):
        from collections import OrderedDict
        self._by_hash: "OrderedDict" = OrderedDict()   # hash -> block id
        self._by_block: dict = {}                      # block id -> hash

    def __len__(self) -> int:
        return len(self._by_hash)

    def get(self, h):
        """Resident block id for hash ``h`` (None = miss); bumps LRU."""
        if h in self._by_hash:
            self._by_hash.move_to_end(h)
            return self._by_hash[h]
        return None

    def put(self, h, block_id: int) -> bool:
        """Register ``block_id`` under ``h``; False if already mapped."""
        if h in self._by_hash:
            return False
        block_id = int(block_id)
        if block_id in self._by_block:
            raise ValueError(
                f"PrefixIndex.put: block {block_id} already registered "
                f"under another hash")
        self._by_hash[h] = block_id
        self._by_block[block_id] = h
        return True

    def pop_block(self, block_id: int):
        """Drop the entry for ``block_id`` (eviction / physical free)."""
        h = self._by_block.pop(int(block_id), None)
        if h is not None:
            del self._by_hash[h]
        return h

    def blocks_lru(self) -> list:
        """Registered block ids, least-recently-matched first."""
        return list(self._by_hash.values())


def paged_adopt_row(cache, row_cache, row, block_ids, *, window: int = 0,
                    src_ring: bool = False):
    """Graft a batch-1 LINEAR prefilled cache into row ``row`` of a paged
    pool cache: the prompt's KV is scattered into the arena blocks named
    by ``block_ids`` and the row's table/``lens`` entries take over.

    ``block_ids``: (W,) int32 physical ids, unassigned entries = the
    ``n_blocks`` sentinel (their scatter is dropped).  ``src_ring`` marks
    a ``row_cache`` whose K/V time axis is in ring layout (a
    sliding-window prefill longer than the window); out-of-window slots
    the block layout covers but the ring never stored arrive as garbage
    and stay masked, exactly as they are in the ring itself.  Unlike
    ``adopt_row`` there is no frontier precondition: row-local
    addressing needs no compaction.
    """
    from repro.models import layers as L

    if not is_paged(cache):
        raise ValueError("paged_adopt_row: pool cache is not paged "
                         "(no block_tables leaf)")
    row = jnp.asarray(row, jnp.int32)
    block_ids = jnp.asarray(block_ids, jnp.int32)
    plen = jnp.asarray(row_cache["lens"], jnp.int32)[0]
    out = dict(cache)
    for key, leaf in cache.items():
        if key in _TIME_LEAVES and key in row_cache:
            out[key] = L.paged_pack(
                leaf, row_cache[key], block_ids[None, :], plen[None],
                window=window, src_shift=None, src_ring=src_ring)
    out["block_tables"] = cache["block_tables"].at[row].set(block_ids)
    out["lens"] = jnp.asarray(cache["lens"], jnp.int32).at[row].set(plen)
    return out


def paged_release_rows(cache, rows):
    """Retire paged batch rows: ``lens -> 0`` and their block-table rows
    reset to the sentinel, so stale entries can neither be written (the
    scatter drops sentinel targets) nor keep referencing blocks the
    caller is about to hand back to the pool.  The arena content itself
    is NOT wiped: freed blocks are overwritten wholesale on their next
    allocation and masked by ``lens`` until then.  The caller owns the
    host-side ``BlockPool.free``.
    """
    if not is_paged(cache):
        raise ValueError("paged_release_rows: cache is not paged")
    rows = jnp.asarray(rows, bool)
    tables = cache["block_tables"]
    sentinel = jnp.full_like(tables, _paged_sentinel(cache))
    return dict(
        cache,
        block_tables=jnp.where(rows[:, None], sentinel, tables),
        lens=jnp.where(rows, 0, jnp.asarray(cache["lens"], jnp.int32)))


def _paged_sentinel(cache) -> int:
    """The invalid block id (== n_blocks, from any arena leaf's shape)."""
    for key in _TIME_LEAVES:
        if key in cache:
            return int(cache[key].shape[1])
    raise ValueError("paged cache has no arena content leaves")
