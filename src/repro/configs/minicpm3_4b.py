"""minicpm3-4b [dense]: MLA [hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H (kv=40 after latent decompression) d_ff=6400
vocab=73448.  Multi-head latent attention: q_lora=768, kv_lora=256,
qk_nope=64, qk_rope=32, v_head=64 — the cache stores only the 288-wide
latent per token.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="transformer",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=96,                  # qk_nope + qk_rope (bookkeeping only)
    d_ff=6400,
    vocab=73448,
    act="silu",
    rope_theta=10000.0,
    mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    compute_dtype="bfloat16",
    grad_compress="posit16",
    grad_accum=4,
    seq_shard_activations=True,
    fsdp=True,
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
