"""phi3-medium-14b [dense]: RoPE SwiGLU GQA [arXiv:2404.14219; unverified].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="transformer",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab=100352,
    act="silu",
    rope_theta=10000.0,
    compute_dtype="bfloat16",
    grad_compress="posit16",
    grad_accum=4,
    fsdp=True,
    seq_shard_activations=True,
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
