"""whisper-tiny [audio]: enc-dec, conv frontend stub [arXiv:2212.04356].

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865; encoder sees 1500
precomputed frame embeddings (the conv frontend is a STUB per the
assignment).  decode_32k runs mechanically (far beyond whisper's 448
context — noted in DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="whisper",
    n_layers=4,                   # decoder layers
    encoder_layers=4,
    encoder_seq=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51865,
    act="gelu",
    compute_dtype="bfloat16",
    grad_compress="posit16",
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
