"""granite-moe-3b-a800m [moe] [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

32L d_model=1536 24H (GQA kv=8) d_ff=512 per expert, vocab=49155,
MoE 40 experts top-8 (experts sharded over the 'model' axis).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="transformer",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    act="silu",
    rope_theta=10000.0,
    n_experts=40,
    top_k=8,
    d_ff_expert=512,
    compute_dtype="bfloat16",
    grad_compress="posit16",
    grad_accum=4,
    seq_shard_activations=True,
    fsdp=True,
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
