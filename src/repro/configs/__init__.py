"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

from . import (dbrx_132b, gemma_7b, granite_34b, granite_moe_3b_a800m,
               hymba_1_5b, internvl2_1b, minicpm3_4b, phi3_medium_14b,
               rwkv6_7b, whisper_tiny)
from .shapes import ALL_SHAPES, SHAPES, ShapeSpec

_MODULES = {
    "internvl2-1b": internvl2_1b,
    "rwkv6-7b": rwkv6_7b,
    "phi3-medium-14b": phi3_medium_14b,
    "gemma-7b": gemma_7b,
    "granite-34b": granite_34b,
    "minicpm3-4b": minicpm3_4b,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "dbrx-132b": dbrx_132b,
    "hymba-1.5b": hymba_1_5b,
    "whisper-tiny": whisper_tiny,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def supported_shapes(arch: str):
    return _MODULES[arch].SUPPORTED_SHAPES


def config_for_cell(arch: str, shape: str) -> ModelConfig:
    """Arch config adjusted for a dry-run cell (serving memory policy)."""
    mod = _MODULES[arch]
    cfg = mod.CONFIG
    spec = SHAPES[shape]
    if spec.kind == "decode":
        overrides = getattr(mod, "SERVE_OVERRIDES",
                            dict(kv_posit="posit16"))
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def all_cells():
    """Every assigned (arch x shape) pair = the dry-run/roofline grid."""
    for arch in ARCH_IDS:
        for shape in supported_shapes(arch):
            yield arch, shape
