"""internvl2-1b [vlm]: InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  The vision
frontend is a STUB per the assignment: ``input_specs`` provides 256
precomputed patch embeddings per sample, prepended to the text tokens.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="transformer",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151655,
    act="silu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,          # internlm2-1.8b ties embeddings
    n_visual_tokens=256,
    compute_dtype="bfloat16",
    grad_compress="posit16",
    grad_accum=4,
    seq_shard_activations=True,
)

# full attention -> long_500k skipped (DESIGN.md §4)
SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
