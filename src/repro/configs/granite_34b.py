"""granite-34b [dense]: llama-arch code model, MQA [arXiv:2405.04324; hf].

88L d_model=6144 48H (GQA kv=1 -> MQA; KV replicated under TP)
d_ff=24576 vocab=49152.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="transformer",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    act="silu",
    rope_theta=10000.0,
    compute_dtype="bfloat16",
    grad_compress="posit16",
    grad_accum=8,
    fsdp=True,
    seq_shard_activations=True,   # 88 layers: activations must seq-shard
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
