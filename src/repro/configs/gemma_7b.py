"""gemma-7b [dense]: GeGLU, head_dim=256 [arXiv:2403.08295; hf].

28L d_model=3072 16H (GQA kv=16 = MHA) d_ff=24576 vocab=256000.
Gemma details: embeddings scaled by sqrt(d); RMSNorm stores (1 + w);
tied unembedding.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="transformer",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    act="gelu",
    rope_theta=10000.0,
    tie_embeddings=True,
    scale_embed=True,
    norm_plus_one=True,
    compute_dtype="bfloat16",
    grad_compress="posit16",
    grad_accum=4,
    fsdp=True,
    seq_shard_activations=True,
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
