"""Assigned input-shape sets (identical for every LM arch).

``kind`` selects which step gets lowered in the dry-run:
  train  -> train_step      (forward+backward+optimizer)
  prefill-> prefill_step    (prompt pass building the cache)
  decode -> serve_step      (1 new token against a seq_len cache)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}
