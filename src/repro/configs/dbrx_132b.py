"""dbrx-132b [moe]: 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 per expert vocab=100352,
MoE 16e top-4.  Serving cells *require* the paper's posit compression:
bf16 weights (264 GB) + bf16 32k-cache do not fit 16 GB/chip at TP=16;
posit8 weights + posit8 KV do (EXPERIMENTS.md §Dry-run).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="transformer",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab=100352,
    act="silu",
    rope_theta=500000.0,
    n_experts=16,
    top_k=4,
    d_ff_expert=10752,
    compute_dtype="bfloat16",
    grad_compress="posit16",
    grad_accum=8,
    fsdp=True,
    seq_shard_activations=True,
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")

# serving memory policy (see module docstring)
SERVE_OVERRIDES = dict(weight_posit="posit8", kv_posit="posit8")
