"""rwkv6-7b [ssm]: Finch, data-dependent decay [arXiv:2404.05892; hf].

32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.  64 heads of 64
(d_att = d_model).  O(1) recurrent state -> runs the long_500k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="rwkv6",
    n_layers=32,
    d_model=4096,
    n_heads=64,                   # d_att / head_dim
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65536,
    decay_lora=64,
    wkv_chunk=16,                 # bounds the (C,C,N) ratio tensor
    compute_dtype="bfloat16",
    grad_compress="posit16",
    grad_accum=4,
    fsdp=True,
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
