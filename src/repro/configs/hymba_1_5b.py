"""hymba-1.5b [hybrid]: parallel attn+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 ssm_state=16.  SWA (1024)
everywhere except 3 global layers {0, 15, 31}; 128 meta tokens.
Sub-quadratic -> runs the long_500k cell (SWA ring caches + 3 full
global caches, sequence-sharded).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hymba",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    act="silu",
    rope_theta=10000.0,
    ssm_state=16,
    ssm_heads=25,
    ssm_head_dim=64,
    sliding_window=1024,
    global_layers=(0, 15, 31),
    n_meta_tokens=128,
    wkv_chunk=64,                 # scalar decay: (C,C) ratios are cheap
    compute_dtype="bfloat16",
    grad_compress="posit16",
    grad_accum=4,
    seq_shard_activations=True,
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
