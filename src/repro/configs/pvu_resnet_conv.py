"""The paper's own verification workload: quantized first-conv data from a
ResNet-18-shaped network, evaluated with the PVU ops (benchmarks use this
config; it is not an LM arch).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ConvWorkload:
    in_channels: int = 3
    out_channels: int = 64
    kernel: int = 7
    image: int = 224
    stride: int = 2
    quant_scale: float = 0.02     # int8-style uniform quantization step


CONFIG = ConvWorkload()
