"""Posit configuration types.

The PVU paper parameterizes three things (``§IV``): the posit bit width
``n``, the exponent field width ``es``, and the mantissa *alignment* width
(the cap on alignment shifts in add/sub/dot).  ``PositConfig`` carries the
same three parameters.  ``align_width=63`` (the full width of the emulated
64-bit datapath) makes add/sub/mul exactly rounded; smaller values mimic a
narrower hardware aligner.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PositConfig:
    nbits: int = 32
    es: int = 2
    align_width: int = 63

    def __post_init__(self):
        if not (2 <= self.nbits <= 32):
            raise ValueError(f"nbits must be in [2, 32], got {self.nbits}")
        if not (0 <= self.es <= 4):
            raise ValueError(f"es must be in [0, 4], got {self.es}")
        if not (1 <= self.align_width <= 63):
            raise ValueError("align_width must be in [1, 63]")

    # ---- derived constants (python ints; used as compile-time scalars) ----
    @property
    def useed(self) -> int:
        return 1 << (1 << self.es)

    @property
    def mask(self) -> int:
        """Mask of the low ``nbits`` bits."""
        return (1 << self.nbits) - 1 if self.nbits < 32 else 0xFFFFFFFF

    @property
    def nar_pattern(self) -> int:
        return 1 << (self.nbits - 1)

    @property
    def maxpos_pattern(self) -> int:
        return (1 << (self.nbits - 1)) - 1

    @property
    def minpos_pattern(self) -> int:
        return 1

    @property
    def max_scale(self) -> int:
        """Largest combined binary exponent (maxpos): (n-2) * 2^es."""
        return (self.nbits - 2) << self.es

    @property
    def min_scale(self) -> int:
        return -self.max_scale

    @property
    def max_frac_bits(self) -> int:
        """Longest possible fraction field: n - 1 (sign) - 2 (min regime) - es."""
        return max(0, self.nbits - 3 - self.es)

    @property
    def storage_dtype(self):
        """Narrowest unsigned dtype that holds a pattern."""
        if self.nbits <= 8:
            return jnp.uint8
        if self.nbits <= 16:
            return jnp.uint16
        return jnp.uint32

    @property
    def name(self) -> str:
        return f"posit{self.nbits}e{self.es}"


# The Posit Standard (2022) fixes es = 2; these are the configs the paper
# evaluates (posit16 / posit32) plus a narrow one for aggressive compression.
POSIT32 = PositConfig(32, 2)
POSIT16 = PositConfig(16, 2)
POSIT8 = PositConfig(8, 2)
POSIT16_E1 = PositConfig(16, 1)
POSIT8_E0 = PositConfig(8, 0)
