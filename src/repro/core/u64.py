"""64-bit unsigned arithmetic emulated as (hi, lo) uint32 pairs.

TPUs (and x64-disabled JAX) have no int64 datapath; the PVU RTL likewise
composes its wide datapaths from 32-bit slices.  Everything here is
branch-free and vectorizes over the VPU lanes.

The multiplier is the TPU-native adaptation of the paper's radix-4 Booth
multiplier + CSA tree: we decompose into 16-bit limbs (hardware-supported
int multiplies) and recombine with explicit carries — the same
"cheap partial products + carry-save recombination" insight, expressed in
the units a TPU actually has.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .bits import U32, clz32, i32, sll, srl, u32


class U64(NamedTuple):
    hi: jnp.ndarray
    lo: jnp.ndarray


def make(hi, lo) -> U64:
    return U64(u32(hi), u32(lo))


def zeros_like(x: U64) -> U64:
    return U64(jnp.zeros_like(x.hi), jnp.zeros_like(x.lo))


def from32(lo) -> U64:
    lo = u32(lo)
    return U64(jnp.zeros_like(lo), lo)


def add(a: U64, b: U64) -> U64:
    lo = a.lo + b.lo
    carry = jnp.where(lo < a.lo, u32(1), u32(0))
    hi = a.hi + b.hi + carry
    return U64(hi, lo)


def sub(a: U64, b: U64) -> U64:
    lo = a.lo - b.lo
    borrow = jnp.where(a.lo < b.lo, u32(1), u32(0))
    hi = a.hi - b.hi - borrow
    return U64(hi, lo)


def neg(a: U64) -> U64:
    """Two's complement: 2^64 - a (mod 2^64)."""
    return add(U64(~a.hi, ~a.lo), from32(u32(1)))


def bor(a: U64, b: U64) -> U64:
    return U64(a.hi | b.hi, a.lo | b.lo)


def band(a: U64, b: U64) -> U64:
    return U64(a.hi & b.hi, a.lo & b.lo)


def shl(a: U64, s) -> U64:
    """a << s, s in [0, 64); total (0 for s >= 64)."""
    s = i32(s)
    hi = sll(a.hi, s) | srl(a.lo, 32 - s) | sll(a.lo, s - 32)
    lo = sll(a.lo, s)
    return U64(hi, lo)


def shr(a: U64, s) -> U64:
    """Logical a >> s, s in [0, 64); total."""
    s = i32(s)
    lo = srl(a.lo, s) | sll(a.hi, 32 - s) | srl(a.hi, s - 32)
    hi = srl(a.hi, s)
    return U64(hi, lo)


def shr_sticky(a: U64, s):
    """(a >> s, sticky) where sticky=1 iff any shifted-out bit was set.

    s in [0, 64); s >= 64 must be pre-clamped by the caller.
    """
    s = i32(s)
    out = shr(a, s)
    # bits shifted out = a & ((1 << s) - 1); compute the mask in u64.
    mask = sub(shl(from32(u32(1)), s), from32(u32(1)))  # 2^s - 1 (s<64)
    dropped = band(a, mask)
    sticky = jnp.where((dropped.hi | dropped.lo) != 0, u32(1), u32(0))
    return out, sticky


def lt(a: U64, b: U64):
    return (a.hi < b.hi) | ((a.hi == b.hi) & (a.lo < b.lo))


def ge(a: U64, b: U64):
    return ~lt(a, b)


def eq(a: U64, b: U64):
    return (a.hi == b.hi) & (a.lo == b.lo)


def is_zero(a: U64):
    return (a.hi | a.lo) == 0


def select(cond, a: U64, b: U64) -> U64:
    return U64(jnp.where(cond, a.hi, b.hi), jnp.where(cond, a.lo, b.lo))


def clz64(a: U64):
    return jnp.where(a.hi == 0, i32(32) + clz32(a.lo), clz32(a.hi))


def bit(a: U64, pos) -> jnp.ndarray:
    """Extract bit ``pos`` (0..63) as uint32 {0,1}."""
    sh = shr(a, pos)
    return sh.lo & u32(1)


def mul_32x32(a, b) -> U64:
    """Full 32x32 -> 64 product via 16-bit limb partial products.

    This is the Booth-multiplier stand-in (see module docstring).
    """
    a = u32(a)
    b = u32(b)
    a0 = a & u32(0xFFFF)
    a1 = a >> u32(16)
    b0 = b & u32(0xFFFF)
    b1 = b >> u32(16)
    p00 = a0 * b0
    p01 = a0 * b1
    p10 = a1 * b0
    p11 = a1 * b1
    mid = p01 + p10
    mid_carry = jnp.where(mid < p01, u32(1), u32(0))  # wrapped past 2^32
    lo = p00 + (mid << u32(16))
    c1 = jnp.where(lo < p00, u32(1), u32(0))
    hi = p11 + (mid >> u32(16)) + (mid_carry << u32(16)) + c1
    return U64(hi, lo)


def mul_64x32_hi64(t: U64, x):
    """Return (t * x) >> 32 as U64 (truncating; error < 1 ulp of the result).

    Used by the Newton-Raphson divider where a truncating recombination is
    exactly what narrow hardware would do.
    """
    x = u32(x)
    a = mul_32x32(t.hi, x)          # contributes at scale 2^32
    b = mul_32x32(t.lo, x)          # contributes at scale 2^0
    return add(a, from32(b.hi))     # (a << 32 + b) >> 32, dropping b.lo
