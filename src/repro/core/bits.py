"""Portable bit primitives on uint32 lanes.

These are the TPU-native stand-ins for the PVU's hardware submodules:

* ``clz32``       — the paper's LZC (leading-zero-count) module, as a
                    branch-free 5-stage binary search (``lax.clz`` does not
                    lower inside Pallas TPU kernels, so we use the same
                    portable formulation everywhere: core, refs, kernels).
* ``sll``/``srl`` — total barrel shifts: well-defined for any amount,
                    returning 0 once the amount reaches the width (XLA's
                    native shift is undefined for amount >= bitwidth).

All helpers take/return ``uint32`` arrays; shift amounts are ``int32``.
"""
from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32
I32 = jnp.int32


def u32(x):
    return jnp.asarray(x, U32)


def i32(x):
    return jnp.asarray(x, I32)


def sll(x, s):
    """x << s with s in [0, 63]; 0 when s >= 32.  x: uint32, s: int32."""
    x = u32(x)
    s = i32(s)
    amt = u32(jnp.clip(s, 0, 31))
    return jnp.where((s >= 0) & (s < 32), x << amt, u32(0))


def srl(x, s):
    """Logical x >> s with s in [0, 63]; 0 when s >= 32."""
    x = u32(x)
    s = i32(s)
    amt = u32(jnp.clip(s, 0, 31))
    return jnp.where((s >= 0) & (s < 32), x >> amt, u32(0))


def clz32(x):
    """Count leading zeros of a uint32 (32 for x == 0).  Branch-free."""
    x = u32(x)
    is_zero = x == 0
    n = jnp.zeros(x.shape, I32)
    cur = x
    for k in (16, 8, 4, 2, 1):
        cond = cur < u32(1 << (32 - k))
        n = n + jnp.where(cond, i32(k), i32(0))
        cur = jnp.where(cond, cur << u32(k), cur)
    return jnp.where(is_zero, i32(32), n)


def parity_mask(cond):
    """Boolean -> uint32 {0,1}."""
    return jnp.where(cond, u32(1), u32(0))
