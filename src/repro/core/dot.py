"""PVU dot product (§IV-E): wide-accumulator vector reduction.

The paper multiplies element-wise, aligns *all* products to the max
exponent, converts to two's complement, and accumulates in a CSA with a
wider bit width, rounding once at the end.  We reproduce that with a
128-bit "quire-lite":

* products are kept unrounded in Q2.62 (u64),
* placed at bits 95..32 of a 128-bit window: 32 bits of carry headroom on
  top (sums of up to 2^31 terms cannot wrap), 32+ alignment bits below
  (only exponent spreads beyond 95 bits fall to a sticky flag),
* accumulated by 16-bit half-limb column sums (the vectorized equivalent
  of the CSA tree: column sums defer carry propagation exactly like
  carry-save addition, with a single propagation at the end),
* normalized and rounded to the target posit exactly once.

The quire is *streamable*: the accumulator state (limb columns +
alignment exponent + sticky + NaR flag) is a first-class value
(``QuireState``) produced per tile by ``quire_partial``, carried across
K-tiles by ``quire_combine`` (re-align to the larger max exponent, add
the 128-bit sums), and rounded exactly once by ``quire_finalize``.
``vpdot`` composes the three, chunking internally, so reduction lengths
are unbounded (up to the 2^31-term carry headroom of the window).

A single *tile* must stay <= ``MAX_DOT_LENGTH`` so the half-limb column
sums stay far from uint32 overflow (bound: L * 0xFFFF + carry < 2^32).

Combine semantics: re-aligning a partial sum floors the *tile subtotal*
(arithmetic shift right, dropped bits -> sticky) where the monolithic
path floors each product individually.  The two agree bit for bit
whenever no nonzero bit is actually dropped by the combine shift — in
particular always for a single tile, and for any data whose product
exponent spread stays inside the 128-bit window.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from . import u64
from .bits import clz32, i32, sll, srl, u32
from .pir import PIR
from .types import PositConfig

_EXP_SENTINEL = -(1 << 28)
MAX_DOT_LENGTH = 4096
_NLIMB = 4  # 128-bit accumulator


def _place_product(p: u64.U64, d):
    """(p * 2^32) >> d as 128-bit limbs [x3..x0] + sticky; d in [0, 95]."""
    d = i32(d)
    # case d <= 63: shift within the top-64 window, spill into x0
    top = u64.shr(p, d)
    spill = u64.shl(p, 64 - d)           # dropped bits, MSB-aligned
    st1 = jnp.where(spill.lo != 0, u32(1), u32(0))
    # case 64 <= d <= 95: whole value lands in (x1, x0)
    low, st2 = u64.shr_sticky(p, d - 32)
    zero = jnp.zeros_like(p.hi)
    x3 = zero
    x2 = jnp.where(d < 64, top.hi, u32(0))
    x1 = jnp.where(d < 64, top.lo, low.hi)
    x0 = jnp.where(d < 64, spill.hi, low.lo)
    st = jnp.where(d < 64, st1, st2)
    return [x3, x2, x1, x0], st


def _neg128(limbs):
    """128-bit two's complement, limbs MSB-first."""
    out = []
    carry = u32(1)
    for x in reversed(limbs):
        t = (~x) + carry
        carry = jnp.where((x == 0) & (carry == 1), u32(1), u32(0))
        out.append(t)
    return list(reversed(out))


def _sub1_128(limbs, dec):
    """Subtract a {0,1} uint32 from 128-bit limbs (MSB-first)."""
    out = []
    borrow = dec
    for x in reversed(limbs):
        t = x - borrow
        borrow = jnp.where(x < borrow, u32(1), u32(0))
        out.append(t)
    return list(reversed(out))


def _sum128(limbs, axis):
    """Sum 128-bit two's-complement limb vectors along ``axis`` (mod 2^128)."""
    halves = []
    for x in reversed(limbs):            # LSB-first halves
        halves.append(x & u32(0xFFFF))
        halves.append(x >> u32(16))
    sums = [jnp.sum(x, axis=axis, dtype=jnp.uint32) for x in halves]
    carry = u32(0)
    out16 = []
    for s in sums:
        t = s + carry
        out16.append(t & u32(0xFFFF))
        carry = t >> u32(16)
    out = []
    for j in range(_NLIMB):
        out.append(out16[2 * j] | (out16[2 * j + 1] << u32(16)))
    return list(reversed(out))           # back to MSB-first


def _clz128(limbs):
    result = jnp.full(limbs[0].shape, 32 * _NLIMB, jnp.int32)
    found = jnp.zeros(limbs[0].shape, bool)
    off = 0
    for x in limbs:                      # MSB-first
        take = (~found) & (x != 0)
        result = jnp.where(take, off + clz32(x), result)
        found = found | (x != 0)
        off += 32
    return result


def _top_and_rest(limbs, lz):
    """Given 128-bit limbs shifted left by ``lz`` (MSB lands at bit 127),
    return (bits 127..96, any-bit-below-96?)."""
    top = jnp.zeros_like(limbs[0])
    rest_nonzero = jnp.zeros(limbs[0].shape, bool)
    nbits = 32 * _NLIMB
    for idx, x in enumerate(limbs):      # MSB-first
        off = 32 * (_NLIMB - 1 - idx)    # limb bit offset: 96, 64, 32, 0
        s = off + lz - (nbits - 32)      # alignment into the top word
        top = top | jnp.where(s >= 0, sll(x, s), srl(x, -s))
        # bits of x*2^(off+lz) below bit 96: width of the low mask.
        # w <= 0 means the whole limb lands at/above bit 96 — nothing
        # below — and MUST short-circuit: sll(1, w) - 1 underflows to
        # all-ones for negative w, which set a spurious sticky on every
        # normalized value and broke round-to-nearest-even ties (caught
        # by the exhaustive posit8 conformance sweep).
        w = (nbits - 32) - (off + lz)
        mask = sll(u32(1), w) - u32(1)
        nz = jnp.where(w >= 32, x != 0,
                       jnp.where(w > 0, (x & mask) != 0, False))
        rest_nonzero = rest_nonzero | nz
    return top, rest_nonzero


def _add_n(a, b):
    """Add two equal-width limb vectors (MSB-first) mod 2^(32*n)."""
    out = []
    carry = u32(0)
    for x, y in zip(reversed(a), reversed(b)):    # LSB-first
        t = x + y
        c1 = jnp.where(t < x, u32(1), u32(0))
        t = t + carry
        c2 = jnp.where(t < carry, u32(1), u32(0))
        out.append(t)
        carry = c1 | c2                 # x+y+carry <= 2^33 - 1: at most one
    return list(reversed(out))


def _asr128_sticky(limbs, s):
    """Arithmetic (two's-complement, i.e. floor) shift right of a 128-bit
    value by ``s`` >= 0 (clamped at 128), limbs MSB-first.

    Returns (shifted limbs, sticky) where sticky is 1 iff any dropped bit
    was set — exactly ``x != floor(x / 2^s) * 2^s``.
    """
    s = jnp.clip(i32(s), 0, 32 * _NLIMB)
    fill = jnp.where((limbs[0] >> u32(31)) != 0, u32(0xFFFFFFFF), u32(0))
    lsb = list(reversed(limbs))          # lsb[j] covers bits 32j..32j+31
    w = s >> 5                           # whole-limb shift, 0..4
    r = s & 31
    out_lsb = []
    for idx in range(_NLIMB):
        res = jnp.broadcast_to(fill, limbs[0].shape)
        for wv in range(_NLIMB + 1):
            lo = lsb[idx + wv] if idx + wv < _NLIMB else fill
            hi = lsb[idx + wv + 1] if idx + wv + 1 < _NLIMB else fill
            val = srl(lo, r) | sll(hi, 32 - r)    # r == 0: sll(hi,32) == 0
            res = jnp.where(w == wv, val, res)
        out_lsb.append(res)
    sticky = jnp.zeros_like(limbs[0])
    for j in range(_NLIMB):              # bits of lsb[j] strictly below s
        t = s - 32 * j
        mask = sll(u32(1), jnp.clip(t, 0, 31)) - u32(1)
        below = jnp.where(t >= 32, lsb[j] != 0, (lsb[j] & mask) != 0)
        sticky = sticky | jnp.where(below, u32(1), u32(0))
    return list(reversed(out_lsb)), sticky


# ---------------------------------------------------------------------------
# Streamable quire-lite: QuireState + partial / combine / finalize
# ---------------------------------------------------------------------------

class QuireState(NamedTuple):
    """Streaming 128-bit quire-lite accumulator state.

    acc    : uint32 (..., 4) — two's-complement limb columns, MSB-first
             along the last axis; the max-exp product's MSB sits at bit 95.
    m_exp  : int32 — the alignment (max product) exponent; the sentinel
             ``-(1 << 28)`` marks an empty/all-zero accumulation.
    sticky : uint32 {0,1} — nonzero bits lost below the window.
    nar    : bool — any NaR operand seen.
    """
    acc: jnp.ndarray
    m_exp: jnp.ndarray
    sticky: jnp.ndarray
    nar: jnp.ndarray


def _unstack_acc(acc):
    return [acc[..., j] for j in range(_NLIMB)]


def quire_partial(a: PIR, b: PIR, axis: int = -1) -> QuireState:
    """Accumulate one K-tile of ``sum_i a_i * b_i`` into a QuireState.

    Bit-identical to the first half of the monolithic paper pipeline:
    elementwise Q2.62 significand products, aligned to the *tile* max
    exponent, floored (sticky) per product, 128-bit column-summed.
    """
    length = a.sig.shape[axis]
    if length > MAX_DOT_LENGTH:
        raise ValueError(
            f"quire_partial tile length {length} exceeds MAX_DOT_LENGTH="
            f"{MAX_DOT_LENGTH} (uint32 half-limb column-sum bound); chunk "
            "the reduction — vpdot / the tiled kernels do this for you")
    psign = a.sign ^ b.sign
    pexp = a.exp + b.exp
    pzero = a.is_zero | b.is_zero
    any_nar = jnp.any(a.is_nar | b.is_nar, axis=axis)

    prod = u64.mul_32x32(a.sig, b.sig)                   # Q2.62
    prod = u64.select(pzero, u64.zeros_like(prod), prod)
    pexp = jnp.where(pzero, i32(_EXP_SENTINEL), pexp)

    m_exp = jnp.max(pexp, axis=axis, keepdims=True)
    d = jnp.clip(m_exp - pexp, 0, 95)
    limbs, st = _place_product(prod, d)
    st = jnp.where(pzero, u32(0), st)
    sticky = jnp.max(st, axis=axis)

    neg = psign == 1
    nlimbs = _neg128(limbs)
    limbs = [jnp.where(neg, n, p) for n, p in zip(nlimbs, limbs)]
    # a negative contribution with truncated tail: true = -(mag + delta),
    # floor = -(mag) - 1 (the sticky flag carries the fractional part).
    dec = jnp.where(neg & (st == 1), u32(1), u32(0))
    limbs = _sub1_128(limbs, dec)

    acc = _sum128(limbs, axis)
    return QuireState(acc=jnp.stack(acc, axis=-1),
                      m_exp=jnp.squeeze(m_exp, axis=axis),
                      sticky=sticky, nar=any_nar)


def quire_combine(s: QuireState, t: QuireState) -> QuireState:
    """Merge two partial quire states (associative up to the floor of
    re-alignment; exact whenever no nonzero bit is dropped).

    Each 128-bit subtotal is floor-shifted (arithmetic >>) to the larger
    alignment exponent, dropped bits fold into sticky, and the aligned
    subtotals add mod 2^128.  Empty states (sentinel m_exp, zero acc)
    are absorbed untouched.
    """
    m = jnp.maximum(s.m_exp, t.m_exp)
    sa, st_a = _asr128_sticky(_unstack_acc(s.acc), m - s.m_exp)
    tb, st_b = _asr128_sticky(_unstack_acc(t.acc), m - t.m_exp)
    acc = _add_n(sa, tb)
    return QuireState(acc=jnp.stack(acc, axis=-1), m_exp=m,
                      sticky=s.sticky | t.sticky | st_a | st_b,
                      nar=s.nar | t.nar)


def quire_finalize(state: QuireState):
    """Normalize + extract the significand: QuireState -> (PIR, sticky).

    The single rounding happens afterwards, at posit encode
    (``pir.encode_pir``) — exactly once per reduction, as in the paper.
    """
    acc = _unstack_acc(state.acc)
    sticky = state.sticky

    sign_out = (acc[0] >> u32(31)) & u32(1)
    nacc = _neg128(acc)
    acc = [jnp.where(sign_out == 1, n, p) for n, p in zip(nacc, acc)]

    nonzero = acc[0]
    for x in acc[1:]:
        nonzero = nonzero | x
    is_zero = (nonzero == 0) & (sticky == 0)

    # normalize: value = mag128 * 2^(m_exp - 94); MSB -> bit 127,
    # significand = bits 127..96.
    lz = _clz128(acc)
    exp_out = state.m_exp + 33 - lz
    top, rest_nz = _top_and_rest(acc, lz)
    sticky = sticky | jnp.where(rest_nz, u32(1), u32(0))

    sig = jnp.where(is_zero, u32(0), top)
    sign_out = jnp.where(is_zero, u32(0), sign_out)
    exp_out = jnp.where(is_zero, i32(0), exp_out)
    pir = PIR(sign=sign_out, exp=exp_out, sig=sig,
              is_zero=is_zero, is_nar=state.nar)
    return pir, sticky


def _move_last(p: PIR, axis: int) -> PIR:
    return PIR(*(jnp.moveaxis(f, axis, -1) for f in p))


# ---------------------------------------------------------------------------
# Exact 512-bit quire (Posit Standard 2022) — beyond-paper mode
# ---------------------------------------------------------------------------
# For posit<32,2>, product bit weights span 2^(exp-62) with exp in
# [-240, 240]; a fixed-point register over [2^-302, 2^178) plus 32 carry
# bits is exactly the standard's 512-bit quire.  Products are placed at
# absolute positions (no alignment, no sticky — the sum is *exact*),
# accumulated by 16-bit half-limb column sums, and rounded once.

_QLIMB = 16                      # 512 bits
_QBIAS = 302                     # shift = exp + _QBIAS in [0, 480]


def _quire_place(p: u64.U64, exp):
    """Place the Q2.62 product at absolute bit offset exp+_QBIAS.
    Returns 16 uint32 limbs (MSB-first)."""
    s = i32(exp) + i32(_QBIAS)
    limbs = []
    for j in range(_QLIMB - 1, -1, -1):     # MSB-first output order
        lo_bit = 32 * j
        d = lo_bit - s
        # window_j = low32( (P << s) >> 32j ) = low32(P >> d) | low32(P << -d)
        right = u64.shr(p, jnp.clip(d, 0, 63)).lo
        right = jnp.where((d >= 0) & (d < 64), right, u32(0))
        left = u64.shl(p, jnp.clip(-d, 0, 63)).lo
        left = jnp.where((d < 0) & (d > -64), left, u32(0))
        limbs.append(right | left)
    return limbs


def _neg_n(limbs):
    out = []
    carry = u32(1)
    for x in reversed(limbs):
        t = (~x) + carry
        carry = jnp.where((x == 0) & (carry == 1), u32(1), u32(0))
        out.append(t)
    return list(reversed(out))


def _sum_n(limbs, axis):
    halves = []
    for x in reversed(limbs):
        halves.append(x & u32(0xFFFF))
        halves.append(x >> u32(16))
    sums = [jnp.sum(x, axis=axis, dtype=jnp.uint32) for x in halves]
    carry = u32(0)
    out16 = []
    for s in sums:
        t = s + carry
        out16.append(t & u32(0xFFFF))
        carry = t >> u32(16)
    n = len(limbs)
    out = [out16[2 * j] | (out16[2 * j + 1] << u32(16)) for j in range(n)]
    return list(reversed(out))


def _quire_exact_partial(a: PIR, b: PIR, axis: int):
    """One <= MAX_DOT_LENGTH tile into the exact 512-bit quire.

    Returns (limbs list[16] MSB-first, any_nar).  Placement is at
    absolute bit positions, so partial sums combine by plain 512-bit
    addition — the exact quire stream is fully associative.
    """
    if a.sig.shape[axis] > MAX_DOT_LENGTH:
        raise ValueError(
            f"_quire_exact_partial tile length {a.sig.shape[axis]} exceeds "
            f"MAX_DOT_LENGTH={MAX_DOT_LENGTH}; chunk the reduction")
    psign = a.sign ^ b.sign
    pexp = a.exp + b.exp
    pzero = a.is_zero | b.is_zero
    any_nar = jnp.any(a.is_nar | b.is_nar, axis=axis)

    prod = u64.mul_32x32(a.sig, b.sig)
    prod = u64.select(pzero, u64.zeros_like(prod), prod)
    limbs = _quire_place(prod, jnp.where(pzero, i32(0), pexp))
    limbs = [jnp.where(pzero, u32(0), x) for x in limbs]
    neg = (psign == 1) & ~pzero
    nl = _neg_n(limbs)
    limbs = [jnp.where(neg, n, p) for n, p in zip(nl, limbs)]
    return _sum_n(limbs, axis), any_nar


def _quire_exact_finalize(acc, any_nar):
    """512-bit quire -> (PIR, sticky); round once at posit encode."""
    sign_out = (acc[0] >> u32(31)) & u32(1)
    nacc = _neg_n(acc)
    acc = [jnp.where(sign_out == 1, n, p) for n, p in zip(nacc, acc)]

    nonzero = acc[0]
    for x in acc[1:]:
        nonzero = nonzero | x
    is_zero = nonzero == 0

    # clz over 512 bits
    lz = jnp.full(acc[0].shape, 32 * _QLIMB, jnp.int32)
    found = jnp.zeros(acc[0].shape, bool)
    off = 0
    for x in acc:
        take = (~found) & (x != 0)
        lz = jnp.where(take, off + clz32(x), lz)
        found = found | (x != 0)
        off += 32
    msb = 511 - lz
    exp_out = msb - (_QBIAS + 62)

    # significand = bits [msb .. msb-31]; sticky = anything below
    sh = msb - 31                             # >= -31
    sig = jnp.zeros_like(acc[0])
    sticky = jnp.zeros_like(acc[0])
    for j in range(_QLIMB):                   # limb j covers bits 32j..+31
        x = acc[_QLIMB - 1 - j]
        d = sh - 32 * j
        hit = srl(x, d) | jnp.where((d < 0) & (d > -32),
                                    sll(x, -d), u32(0))
        sig = sig | jnp.where((d > -32) & (d < 32), hit, u32(0))
        below = jnp.where(d >= 32, x != 0,
                          jnp.where(d > 0, (x & (sll(u32(1), d) - 1)) != 0,
                                    False))
        sticky = sticky | jnp.where(below, u32(1), u32(0))

    sig = jnp.where(is_zero, u32(0), sig)
    sign_out = jnp.where(is_zero, u32(0), sign_out)
    exp_out = jnp.where(is_zero, i32(0), exp_out)
    return PIR(sign=sign_out, exp=exp_out, sig=sig,
               is_zero=is_zero, is_nar=any_nar), sticky


def _iter_chunks(a: PIR, b: PIR, length: int):
    for start in range(0, length, MAX_DOT_LENGTH):
        stop = min(start + MAX_DOT_LENGTH, length)
        yield (PIR(*(f[..., start:stop] for f in a)),
               PIR(*(f[..., start:stop] for f in b)))


def vpdot_quire(a: PIR, b: PIR, cfg: PositConfig, axis: int = -1):
    """Exact dot product through the 512-bit standard quire -> (PIR,
    sticky).  Every real sum in quire range is represented exactly; the
    single rounding happens at posit encode.

    Any reduction length: tiles of MAX_DOT_LENGTH stream through the
    quire by exact 512-bit addition (no alignment, order-independent).
    """
    if cfg.nbits > 32 or cfg.es > 2:
        raise ValueError("quire sizing assumes posit<=32, es<=2")
    length = a.sig.shape[axis]
    if length <= MAX_DOT_LENGTH:
        return _quire_exact_finalize(*_quire_exact_partial(a, b, axis))
    a = _move_last(a, axis)
    b = _move_last(b, axis)
    acc, nar = None, None
    for ac, bc in _iter_chunks(a, b, length):
        part, pnar = _quire_exact_partial(ac, bc, -1)
        acc = part if acc is None else _add_n(acc, part)
        nar = pnar if nar is None else (nar | pnar)
    return _quire_exact_finalize(acc, nar)


def vpdot(a: PIR, b: PIR, cfg: PositConfig, axis: int = -1):
    """Reduce ``sum_i a_i * b_i`` along ``axis`` -> (PIR, sticky); rounded
    once (the paper's single-rounding wide accumulator).

    Any reduction length: tiles of MAX_DOT_LENGTH stream through
    ``quire_partial`` / ``quire_combine`` — bit-identical to the
    monolithic pipeline for lengths <= MAX_DOT_LENGTH (a single tile).
    """
    del cfg
    length = a.sig.shape[axis]
    if length <= MAX_DOT_LENGTH:
        return quire_finalize(quire_partial(a, b, axis=axis))
    a = _move_last(a, axis)
    b = _move_last(b, axis)
    state = None
    for ac, bc in _iter_chunks(a, b, length):
        part = quire_partial(ac, bc, axis=-1)
        state = part if state is None else quire_combine(state, part)
    return quire_finalize(state)
