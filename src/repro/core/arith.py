"""PVU arithmetic on the PIR domain (add/sub/mul/div).

Mirrors the paper's datapath (§IV-B/C/D):

* add/sub — comparator picks the max exponent, the smaller operand is
  barrel-shifted with guard/sticky, magnitudes combine, and the result is
  renormalized.  With the default ``align_width=63`` every add/sub is
  *exactly rounded* (the emulated 64-bit datapath keeps 31 guard bits plus a
  sticky, which the analysis in DESIGN.md shows is sufficient).
* mul — full 32x32 significand product via 16-bit limb partial products
  (the TPU-native stand-in for the radix-4 Booth + CSA tree), single RNE.
* div — sign/exponent like mul; significand reciprocal via the paper's
  3-iteration Newton-Raphson in truncating fixed point (this faithfully
  reproduces the paper's ~95.8 % exact-match characteristic), then reuse of
  the multiplier.  ``mode='exact'`` swaps in a restoring long division
  (beyond-paper; 100 % exactly rounded).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import u64
from .bits import i32, u32
from .pir import PIR
from .types import PositConfig

_EXP_SENTINEL = -(1 << 28)  # stands in for -inf when an operand is zero


def negate(p: PIR) -> PIR:
    """Posit negation is exact: flip the sign (zero/NaR unchanged)."""
    sign = jnp.where(p.is_zero | p.is_nar, p.sign, p.sign ^ u32(1))
    return p._replace(sign=sign)


def _sig_to_u64(sig):
    """Q1.31 sig -> u64 with the implicit 1 at bit 62 (31 guard bits)."""
    return u64.U64(sig >> u32(1), sig << u32(31))


def _normalize_u64(mag: u64.U64, exp, sticky):
    """Renormalize so the MSB sits at bit 62; return (sig, exp, sticky).

    Handles both the carry-out case (MSB at 63) and cancellation (MSB
    anywhere below 62).  DESIGN.md shows sticky can only be nonzero when
    the left-shift is <= 1, so tail handling stays exact.
    """
    lz = u64.clz64(mag)                       # 0..64
    sh_l = jnp.maximum(lz - 1, 0)
    left = u64.shl(mag, sh_l)
    right, st_r = u64.shr_sticky(mag, i32(1))
    out = u64.select(lz == 0, right, left)
    sticky = sticky | jnp.where(lz == 0, st_r, u32(0))
    exp_out = exp + 1 - lz
    sig = (out.hi << u32(1)) | (out.lo >> u32(31))
    sticky = sticky | jnp.where((out.lo & u32(0x7FFFFFFF)) != 0, u32(1), u32(0))
    return sig, exp_out, sticky


def vpadd(a: PIR, b: PIR, cfg: PositConfig):
    """Vector posit add on PIRs -> (PIR, sticky)."""
    ea = jnp.where(a.is_zero, i32(_EXP_SENTINEL), a.exp)
    eb = jnp.where(b.is_zero, i32(_EXP_SENTINEL), b.exp)
    exp_t = jnp.maximum(ea, eb)

    d_a = jnp.clip(exp_t - ea, 0, 63)
    d_b = jnp.clip(exp_t - eb, 0, 63)
    m_a, st_a = u64.shr_sticky(_sig_to_u64(a.sig), d_a)
    m_b, st_b = u64.shr_sticky(_sig_to_u64(b.sig), d_b)
    # hardware aligner width (paper's third parameter): shifts beyond it
    # flush the operand entirely (value survives only through sticky).
    if cfg.align_width < 63:
        over_a = d_a > cfg.align_width
        over_b = d_b > cfg.align_width
        st_a = jnp.where(over_a & (a.sig != 0), u32(1), st_a)
        st_b = jnp.where(over_b & (b.sig != 0), u32(1), st_b)
        m_a = u64.select(over_a, u64.zeros_like(m_a), m_a)
        m_b = u64.select(over_b, u64.zeros_like(m_b), m_b)

    same = a.sign == b.sign
    a_ge_b = u64.ge(m_a, m_b)
    ssum = u64.add(m_a, m_b)
    diff = u64.select(a_ge_b, u64.sub(m_a, m_b), u64.sub(m_b, m_a))
    st = st_a | st_b  # at most one is nonzero (only the smaller shifts)
    # subtraction with a truncated tail: true = diff - delta, delta in (0,1)
    # ulp -> floor is diff-1 with sticky set.
    diff = u64.select((~same) & (st == 1), u64.sub(diff, u64.from32(u32(1))),
                      diff)
    mag = u64.select(same, ssum, diff)
    sign = jnp.where(same, a.sign, jnp.where(a_ge_b, a.sign, b.sign))

    sig, exp, sticky = _normalize_u64(mag, exp_t, st)

    out_zero = u64.is_zero(mag) & (st == 0)
    sign = jnp.where(out_zero, u32(0), sign)

    # zero operands: the other passes through untouched (exactly)
    sign = jnp.where(a.is_zero, b.sign, jnp.where(b.is_zero, a.sign, sign))
    exp = jnp.where(a.is_zero, b.exp, jnp.where(b.is_zero, a.exp, exp))
    sig = jnp.where(a.is_zero, b.sig, jnp.where(b.is_zero, a.sig, sig))
    sticky = jnp.where(a.is_zero | b.is_zero, u32(0), sticky)
    is_zero = jnp.where(a.is_zero, b.is_zero,
                        jnp.where(b.is_zero, a.is_zero, out_zero))
    is_nar = a.is_nar | b.is_nar
    return PIR(sign, exp, sig, is_zero, is_nar), sticky


def vpsub(a: PIR, b: PIR, cfg: PositConfig):
    return vpadd(a, negate(b), cfg)


def vpmul(a: PIR, b: PIR, cfg: PositConfig):
    """Vector posit multiply on PIRs -> (PIR, sticky)."""
    del cfg
    sign = a.sign ^ b.sign
    exp = a.exp + b.exp
    prod = u64.mul_32x32(a.sig, b.sig)        # Q2.62, value in [1, 4)
    hi_set = (prod.hi >> u32(31)) != 0        # bit 63 -> value >= 2
    sig_hi = prod.hi                          # bits 63..32
    st_hi = jnp.where(prod.lo != 0, u32(1), u32(0))
    sig_lo = (prod.hi << u32(1)) | (prod.lo >> u32(31))
    st_lo = jnp.where((prod.lo & u32(0x7FFFFFFF)) != 0, u32(1), u32(0))
    sig = jnp.where(hi_set, sig_hi, sig_lo)
    sticky = jnp.where(hi_set, st_hi, st_lo)
    exp = exp + jnp.where(hi_set, i32(1), i32(0))

    is_zero = a.is_zero | b.is_zero
    is_nar = a.is_nar | b.is_nar
    sign = jnp.where(is_zero | is_nar, u32(0), sign)
    sig = jnp.where(is_zero, u32(0), sig)
    sticky = jnp.where(is_zero, u32(0), sticky)
    return PIR(sign, exp, sig, is_zero, is_nar), sticky


# ---------------------------------------------------------------------------
# Division
# ---------------------------------------------------------------------------

# Newton-Raphson seed x0 = 48/17 - 32/17 * c for c in [0.5, 1), in Q1.31.
_K1_Q31 = int(round(48 / 17 * (1 << 31)))   # needs 33 bits -> kept as u64
_K2_Q31 = int(round(32 / 17 * (1 << 31)))   # fits 32 bits


def _nr_reciprocal(sig_b, iters: int = 3):
    """Approximate 2^63 / sig_b (i.e. 1/c for c = sig_b * 2^-32 in (0.5, 1)).

    Returns x in Q1.31 (value = x * 2^-31 in (1, 2)).  Truncating fixed
    point throughout — this is the hardware-faithful path whose residual
    error gives the paper its 95.84 % division accuracy.
    """
    term = u64.mul_32x32(u32(_K2_Q31), sig_b).hi      # (K2 * c) in Q1.31
    k1 = u64.make(jnp.full_like(sig_b, _K1_Q31 >> 32),
                  jnp.full_like(sig_b, _K1_Q31 & 0xFFFFFFFF))
    x = u64.sub(k1, u64.from32(term)).lo              # x0 in Q1.31 (< 2^32)
    for _ in range(iters):
        t = u64.mul_32x32(sig_b, x)                   # c*x in Q2.62-ish
        tm = u64.neg(t)                               # (2 - c*x) at 2^63 scale
        hi = u64.mul_64x32_hi64(tm, x)                # (x*tm) >> 32
        x = (hi.hi << u32(1)) | (hi.lo >> u32(31))    # >> 63 overall -> Q1.31
    return x


def _div_exact_sig(sig_a, sig_b):
    """Exactly-rounded significand quotient via restoring long division.

    Computes q = sig_a / sig_b in (0.5, 2) with 33 quotient bits + exact
    remainder -> (sig Q1.31 normalized, exp_adjust, sticky).
    """
    # Pre-step establishes the invariant rem < den (ratio's integer bit),
    # then 33 shift-subtract steps develop q = floor(sig_a * 2^33 / sig_b).
    den = u64.from32(sig_b)
    ge0 = sig_a >= sig_b
    q = u64.from32(jnp.where(ge0, u32(1), u32(0)))
    rem = u64.from32(jnp.where(ge0, sig_a - sig_b, sig_a))

    def body(_, carry):
        q, rem = carry
        rem = u64.shl(rem, i32(1))
        geq = u64.ge(rem, den)
        rem = u64.select(geq, u64.sub(rem, den), rem)
        q = u64.add(u64.shl(q, i32(1)),
                    u64.from32(jnp.where(geq, u32(1), u32(0))))
        return q, rem

    q, rem = jax.lax.fori_loop(0, 33, body, (q, rem))
    sticky = jnp.where(u64.is_zero(rem), u32(0), u32(1))
    # q in (2^32, 2^34); value = q * 2^-33.
    # ratio >= 1 <=> bit 33 set: sig = q >> 2; else sig = q >> 1, exp -1.
    bit33 = (q.hi >> u32(1)) & u32(1)
    sig_hi, st_hi = u64.shr_sticky(q, i32(2))
    sig_lo, st_lo = u64.shr_sticky(q, i32(1))
    sig = jnp.where(bit33 == 1, sig_hi.lo, sig_lo.lo)
    sticky = sticky | jnp.where(bit33 == 1, st_hi, st_lo)
    exp_adj = jnp.where(bit33 == 1, i32(0), i32(-1))
    return sig, exp_adj, sticky


def vpdiv(a: PIR, b: PIR, cfg: PositConfig, mode: str = "nr3"):
    """Vector posit divide -> (PIR, sticky).

    mode='nr3'   paper-faithful Newton-Raphson, 3 iterations (§IV-D).
    mode='exact' beyond-paper exactly-rounded restoring division.
    """
    del cfg
    sign = a.sign ^ b.sign
    exp = a.exp - b.exp

    if mode == "exact":
        sig, exp_adj, sticky = _div_exact_sig(a.sig, b.sig)
        exp = exp + exp_adj
    elif mode == "nr3":
        x = _nr_reciprocal(b.sig, iters=3)
        prod = u64.mul_32x32(a.sig, x)        # value ~= 2*a/b in Q2.62
        # NR truncation can land the product marginally below 1.0, so use
        # the general renormalizer (handles MSB at 63, 62, or below).
        sig, exp, sticky = _normalize_u64(prod, exp, u32(jnp.zeros_like(x)))
        exp = exp - 1                          # fold the factor-of-2
        # exact shortcut when dividing by a power of two (sig_b == 1.0);
        # also guarantees q == a for b == 1 like the hardware fast path.
        pow2 = b.sig == u32(0x80000000)
        sig = jnp.where(pow2, a.sig, sig)
        sticky = jnp.where(pow2, u32(0), sticky)
        exp = jnp.where(pow2, a.exp - b.exp, exp)
    else:
        raise ValueError(f"unknown div mode {mode!r}")

    is_nar = a.is_nar | b.is_nar | b.is_zero  # x/0 = NaR (posit standard)
    is_zero = a.is_zero & ~b.is_zero
    sign = jnp.where(is_zero | is_nar, u32(0), sign)
    sig = jnp.where(is_zero, u32(0), sig)
    sticky = jnp.where(is_zero, u32(0), sticky)
    return PIR(sign, exp, sig, is_zero, is_nar), sticky
