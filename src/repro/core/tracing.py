"""JAX tracing introspection shared across layers (single home for the
``Tracer`` import shim — jax has moved the class between versions)."""
from __future__ import annotations

try:
    from jax.core import Tracer as _Tracer
except ImportError:                          # pragma: no cover - old jax
    from jax._src.core import Tracer as _Tracer


def is_tracer(x) -> bool:
    """True when ``x`` is an abstract traced value (inside jit/scan/...)
    rather than a concrete array — host-side guards cannot inspect it."""
    return isinstance(x, _Tracer)
