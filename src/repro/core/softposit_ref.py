"""Exact Python golden model for posit arithmetic (SoftPosit-equivalent).

Used as the oracle in tests and benchmarks: pure-integer/Fraction math, no
floating point anywhere, so every result is *provably* correctly rounded.

Rounding rule (Posit Standard 2022 / SoftPosit): round to nearest; ties to
the pattern with LSB 0 (patterns are monotone in value, so pattern-RNE is
value-RNE); magnitudes below minpos round to minpos, above maxpos to
maxpos; no signed zero; NaR absorbs everything undefined.
"""
from __future__ import annotations

import functools
from fractions import Fraction

from .types import PositConfig

ZERO = "zero"
NAR = "nar"


def _decode_bits(pattern: int, n: int, es: int):
    """Pattern -> Fraction | ZERO | NAR, for arbitrary widths (used both
    for cfg widths and the (n+1)-bit rounding-midpoint extension)."""
    mask = (1 << n) - 1
    p = pattern & mask
    if p == 0:
        return ZERO
    if p == 1 << (n - 1):
        return NAR
    sign = (p >> (n - 1)) & 1
    if sign:
        p = (-p) & mask
    # regime
    bits = [(p >> i) & 1 for i in range(n - 2, -1, -1)]  # after the sign
    r0 = bits[0]
    k = 0
    for b in bits:
        if b == r0:
            k += 1
        else:
            break
    r = (k - 1) if r0 == 1 else -k
    rest = bits[k + 1:] if k < len(bits) else []          # skip terminator
    e_bits = rest[:es]
    e = 0
    for b in e_bits:
        e = (e << 1) | b
    e <<= (es - len(e_bits))                              # pad missing with 0
    f_bits = rest[es:]
    f = Fraction(0)
    for i, b in enumerate(f_bits):
        if b:
            f += Fraction(1, 2 ** (i + 1))
    scale = r * (1 << es) + e
    mag = (1 + f) * (Fraction(2) ** scale)
    return -mag if sign else mag


def decode_exact(pattern: int, cfg: PositConfig):
    """Pattern -> Fraction | ZERO | NAR."""
    return _decode_bits(pattern, cfg.nbits, cfg.es)


@functools.lru_cache(maxsize=None)
def _decode_cached(pattern: int, nbits: int, es: int):
    return _decode_bits(pattern, nbits, es)


def encode_exact(value, cfg: PositConfig) -> int:
    """Fraction | ZERO | NAR -> pattern, rounded like SoftPosit.

    SoftPosit (the paper's golden) rounds the *bit string* at n bits with
    RNE — equivalent to comparing against the (n+1)-bit extension pattern
    ``(lo << 1) | 1``, NOT against the value-space midpoint.  The two
    differ when regime growth cuts into exponent bits (tapered ulps).
    """
    if value is NAR:
        return cfg.nar_pattern
    if value is ZERO or value == 0:
        return 0
    v = Fraction(value)
    sign = v < 0
    mag = -v if sign else v

    n, es = cfg.nbits, cfg.es
    maxpos = _decode_cached(cfg.maxpos_pattern, n, es)
    minpos = _decode_cached(cfg.minpos_pattern, n, es)
    if mag >= maxpos:
        p = cfg.maxpos_pattern
    elif mag <= minpos:
        p = cfg.minpos_pattern
    else:
        # binary search: largest positive pattern with value <= mag
        lo, hi = 1, cfg.maxpos_pattern            # values are monotone
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if _decode_cached(mid, n, es) <= mag:
                lo = mid
            else:
                hi = mid - 1
        below = _decode_cached(lo, n, es)
        if below == mag:
            p = lo
        else:
            # bit-string midpoint: the (n+1)-bit posit (lo<<1)|1
            midpoint = _decode_cached((lo << 1) | 1, n + 1, es)
            if mag < midpoint:
                p = lo
            elif mag > midpoint:
                p = lo + 1
            else:                                  # tie -> even pattern
                p = lo if (lo & 1) == 0 else lo + 1
    if sign:
        p = (-p) & cfg.mask
    return p


def _binary(op, a: int, b: int, cfg: PositConfig) -> int:
    va = decode_exact(a, cfg)
    vb = decode_exact(b, cfg)
    if va is NAR or vb is NAR:
        return cfg.nar_pattern
    return op(va, vb)


def add(a: int, b: int, cfg: PositConfig) -> int:
    def op(va, vb):
        va = 0 if va is ZERO else va
        vb = 0 if vb is ZERO else vb
        return encode_exact(va + vb, cfg)
    return _binary(op, a, b, cfg)


def sub(a: int, b: int, cfg: PositConfig) -> int:
    def op(va, vb):
        va = 0 if va is ZERO else va
        vb = 0 if vb is ZERO else vb
        return encode_exact(va - vb, cfg)
    return _binary(op, a, b, cfg)


def mul(a: int, b: int, cfg: PositConfig) -> int:
    def op(va, vb):
        if va is ZERO or vb is ZERO:
            return 0
        return encode_exact(va * vb, cfg)
    return _binary(op, a, b, cfg)


def div(a: int, b: int, cfg: PositConfig) -> int:
    def op(va, vb):
        if vb is ZERO:
            return cfg.nar_pattern               # x/0 = NaR
        if va is ZERO:
            return 0
        return encode_exact(va / vb, cfg)
    return _binary(op, a, b, cfg)


def dot(a_vec, b_vec, cfg: PositConfig) -> int:
    """Exact real dot product, rounded once (quire semantics)."""
    total = Fraction(0)
    for a, b in zip(a_vec, b_vec):
        va = decode_exact(int(a), cfg)
        vb = decode_exact(int(b), cfg)
        if va is NAR or vb is NAR:
            return cfg.nar_pattern
        if va is ZERO or vb is ZERO:
            continue
        total += va * vb
    return encode_exact(total, cfg)


def from_float(x: float, cfg: PositConfig) -> int:
    """Exact f64 -> posit (floats are exact binary rationals)."""
    import math
    if math.isnan(x) or math.isinf(x):
        return cfg.nar_pattern
    if x == 0:
        return 0
    return encode_exact(Fraction(x), cfg)


def to_float(p: int, cfg: PositConfig) -> float:
    v = decode_exact(p, cfg)
    if v is NAR:
        return float("nan")
    if v is ZERO:
        return 0.0
    return float(v)
