"""Public PVU vector API — the software surface of the paper's RVV ISA.

The paper exposes five custom RVV instructions (Table II):
``vpadd / vpsub / vpmul / vpdiv / vpdot``.  Here the same five operations
are the public library API, operating on posit *pattern* arrays (uint8/
uint16/uint32 depending on ``cfg.nbits``).  Each call is
decode -> PIR compute -> single-rounding encode, exactly like one pass
through the hardware pipeline of Fig. 3.

All functions are jit-compatible, vectorized, and differentiable-free
(integer domain); use ``repro.core.convert`` to cross into float land.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import arith, dot as dot_mod
from .convert import f32_to_posit, posit_to_f32, quant_dequant  # re-export
from .pir import decode, encode_pir
from .types import (POSIT8, POSIT16, POSIT32, PositConfig)  # re-export

__all__ = [
    "vpadd", "vpsub", "vpmul", "vpdiv", "vpdot",
    "f32_to_posit", "posit_to_f32", "quant_dequant",
    "PositConfig", "POSIT8", "POSIT16", "POSIT32",
]


def _u(p):
    return jnp.asarray(p).astype(jnp.uint32)


def _pack(p, cfg: PositConfig):
    return p.astype(cfg.storage_dtype)


def vpadd(a, b, cfg: PositConfig = POSIT32):
    pir, sticky = arith.vpadd(decode(_u(a), cfg), decode(_u(b), cfg), cfg)
    return _pack(encode_pir(pir, cfg, sticky), cfg)


def vpsub(a, b, cfg: PositConfig = POSIT32):
    pir, sticky = arith.vpsub(decode(_u(a), cfg), decode(_u(b), cfg), cfg)
    return _pack(encode_pir(pir, cfg, sticky), cfg)


def vpmul(a, b, cfg: PositConfig = POSIT32):
    pir, sticky = arith.vpmul(decode(_u(a), cfg), decode(_u(b), cfg), cfg)
    return _pack(encode_pir(pir, cfg, sticky), cfg)


def vpdiv(a, b, cfg: PositConfig = POSIT32, mode: str = "nr3"):
    """mode='nr3' is the paper-faithful Newton-Raphson divider;
    mode='exact' is the beyond-paper exactly-rounded divider."""
    pir, sticky = arith.vpdiv(decode(_u(a), cfg), decode(_u(b), cfg), cfg,
                              mode=mode)
    return _pack(encode_pir(pir, cfg, sticky), cfg)


def vpdot(a, b, cfg: PositConfig = POSIT32, axis: int = -1,
          mode: str = "quire_lite"):
    """Dot product along ``axis`` with a single final rounding (§IV-E).

    mode='quire_lite' — 128-bit max-exponent-aligned accumulator (the
        paper's CSA design, exact for spreads up to 95 bits);
    mode='quire'      — the Posit Standard's exact 512-bit quire
        (beyond paper; every in-range sum is exact).
    """
    da, db = decode(_u(a), cfg), decode(_u(b), cfg)
    if mode == "quire":
        pir, sticky = dot_mod.vpdot_quire(da, db, cfg, axis=axis)
    else:
        pir, sticky = dot_mod.vpdot(da, db, cfg, axis=axis)
    return _pack(encode_pir(pir, cfg, sticky), cfg)


def vpneg(a, cfg: PositConfig = POSIT32):
    """Exact negation (two's complement of the pattern)."""
    x = _u(a) & jnp.uint32(cfg.mask)
    nar = jnp.uint32(cfg.nar_pattern)
    out = jnp.where((x == 0) | (x == nar), x,
                    (~x + jnp.uint32(1)) & jnp.uint32(cfg.mask))
    return _pack(out, cfg)


@functools.partial(jax.jit, static_argnames=("cfg", "block"))
def posit_matmul(a_f32, w_patterns, cfg: PositConfig = POSIT16,
                 block: int = 512):
    """Reference posit-weight matmul: dequantize ``w`` then MXU matmul.

    The fused-VMEM version lives in ``repro.kernels.posit_gemm``; this is
    the semantically identical composition used on backends without Pallas.
    """
    w = posit_to_f32(w_patterns, cfg)
    return jnp.dot(a_f32, w, preferred_element_type=jnp.float32)
