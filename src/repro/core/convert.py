"""Exact float32 <-> posit conversion (vectorized bit manipulation).

These are the framework's quantize/dequantize primitives: gradients, weight
tiles and KV-cache blocks cross the posit boundary through these two
functions (or their Pallas kernel equivalents in ``repro.kernels``).

Both directions are exactly rounded (RNE).  Conventions:
  f32 NaN/Inf -> NaR;  NaR -> f32 NaN;  +/-0 -> posit 0 -> f32 +0.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .bits import clz32, i32, sll, srl, u32
from .pir import PIR, decode, encode
from .types import PositConfig


def f32_to_posit(x, cfg: PositConfig):
    """float32 array -> posit patterns in ``cfg.storage_dtype``."""
    # bitcast_convert_type (not .view) so the same code lowers in Pallas
    bits = lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.uint32)
    sign = bits >> u32(31)
    exp8 = (bits >> u32(23)) & u32(0xFF)
    man = bits & u32(0x7FFFFF)

    is_nar = exp8 == u32(255)                      # inf or nan
    is_zero = (exp8 == 0) & (man == 0)

    # normal numbers
    exp_n = exp8.astype(jnp.int32) - 127
    sig_n = u32(0x80000000) | (man << u32(8))

    # subnormals: value = man * 2^-149; normalize via clz
    sh = clz32(man)                                # >= 9 for nonzero man
    sig_s = sll(man, sh)
    exp_s = i32(-118) - sh

    subnormal = (exp8 == 0) & (man != 0)
    sig = jnp.where(subnormal, sig_s, sig_n)
    exp = jnp.where(subnormal, exp_s, exp_n)

    p = encode(sign, exp, sig, jnp.zeros_like(sign), is_zero, is_nar, cfg)
    return p.astype(cfg.storage_dtype)


def posit_to_f32(p, cfg: PositConfig):
    """posit patterns -> float32, exactly rounded (RNE)."""
    pir: PIR = decode(jnp.asarray(p).astype(jnp.uint32), cfg)
    sign, exp, sig = pir.sign, pir.exp, pir.sig

    # Uniform rounding: take the mantissa field as sig >> r, round at bit
    # r-1, sticky below.  r = 8 emits a normal (hidden bit masked off);
    # for exp < -126 the value is an f32 subnormal and r grows so the
    # hidden bit lands *inside* the field.
    is_sub = exp < i32(-126)
    t = jnp.clip(-(exp + i32(118)), 9, 40)         # subnormal shift
    r = jnp.where(is_sub, t, i32(8))

    pre = srl(sig, r)
    round_bit = srl(sig, r - 1) & u32(1)
    mask = sll(u32(1), r - 1) - u32(1)             # r-1>=32 -> wraps to all-1s
    sticky = jnp.where((sig & mask) != 0, u32(1), u32(0))

    man = pre & u32(0x7FFFFF)
    inc = round_bit & (sticky | (man & u32(1)))
    man_r = man + inc
    carry = (man_r >> u32(23)).astype(jnp.int32)
    man_f = man_r & u32(0x7FFFFF)

    exp_f = jnp.where(is_sub, i32(-127), exp) + carry
    biased = exp_f + 127
    overflow = biased > 254
    biased = jnp.clip(biased, 0, 254)

    out = (sign << u32(31)) | (biased.astype(jnp.uint32) << u32(23)) | man_f
    inf = (sign << u32(31)) | u32(0x7F800000)
    out = jnp.where(overflow, inf, out)
    out = jnp.where(pir.is_zero, sign << u32(31), out)
    out = jnp.where(pir.is_nar, u32(0x7FC00000), out)
    return lax.bitcast_convert_type(out, jnp.float32)


def quant_dequant(x, cfg: PositConfig):
    """Round-trip f32 -> posit -> f32: the straight-through quantizer."""
    return posit_to_f32(f32_to_posit(x, cfg), cfg)
