"""repro.core — the PVU posit number system in JAX.

Public surface: ``repro.core.posit`` (the vector ISA), ``PositConfig``,
and the f32 converters.
"""
from .types import (POSIT8, POSIT8_E0, POSIT16, POSIT16_E1, POSIT32,
                    PositConfig)
from .convert import f32_to_posit, posit_to_f32, quant_dequant
from .posit import vpadd, vpdiv, vpdot, vpmul, vpneg, vpsub

__all__ = [
    "PositConfig", "POSIT8", "POSIT8_E0", "POSIT16", "POSIT16_E1", "POSIT32",
    "f32_to_posit", "posit_to_f32", "quant_dequant",
    "vpadd", "vpsub", "vpmul", "vpdiv", "vpdot", "vpneg",
]
