"""Posit <-> PIR (Posit Intermediate Representation) codecs.

Faithful vectorized implementation of the paper's decode pipeline
(``Logic 1``) and its inverse (``§IV-G Encode``):

    decode:  sign extract -> two's-complement abs -> LZC over the regime ->
             barrel-shift out regime/terminator -> exponent field ->
             fraction with implicit bit -> PIR(sign, exp, sig)
    encode:  clamp scale -> split scale into (regime r, exponent e) ->
             emit regime/exponent/fraction into a 64-bit stream ->
             round-to-nearest-even on the pattern (posit patterns are
             monotone in value, so pattern-RNE == value-RNE; this is the
             SoftPosit rounding rule) -> saturate -> two's complement sign.

PIR conventions
---------------
sign : uint32 {0,1}
exp  : int32, the *combined* binary scale  r * 2^es + e
sig  : uint32, Q1.31 significand (bit 31 is the implicit leading 1);
       sig == 0 only for zero.
sticky : uint32 {0,1}; 1 iff the true value has nonzero bits strictly
       below sig's LSB (needed for exact RNE after arithmetic).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from . import u64
from .bits import clz32, i32, sll, srl, u32
from .types import PositConfig


class PIR(NamedTuple):
    sign: jnp.ndarray     # uint32 {0,1}
    exp: jnp.ndarray      # int32 combined scale
    sig: jnp.ndarray      # uint32 Q1.31 (bit31 = implicit 1)
    is_zero: jnp.ndarray  # bool
    is_nar: jnp.ndarray   # bool


def decode(p, cfg: PositConfig) -> PIR:
    """Logic 1 of the paper, vectorized over uint32 lanes."""
    n, es = cfg.nbits, cfg.es
    x = u32(p) & u32(cfg.mask)
    is_zero = x == 0
    is_nar = x == u32(cfg.nar_pattern)

    sign = (x >> u32(n - 1)) & u32(1)
    # two's-complement absolute value (paper: "modified to its two's
    # complement representation")
    ax = jnp.where(sign == 1, (~x + u32(1)) & u32(cfg.mask), x)

    # place the sign at bit 31 so field positions are width-independent
    y = ax << u32(32 - n) if n < 32 else ax

    r0 = (y >> u32(30)) & u32(1)
    t = jnp.where(r0 == 1, ~y, y) & u32(0x7FFFFFFF)
    t = t << u32(1)  # regime run now starts at bit 31
    # run length (the LZC module); a full-width run (maxpos/minpos extremes
    # at n == 32) makes t == 0 -> clz 32, so clamp to the legal max n-1.
    k = jnp.minimum(clz32(t), n - 1)
    r = jnp.where(r0 == 1, k - 1, -k)

    # shift off sign + regime run + terminator -> exponent at the top
    body = sll(y, k + 2)
    if es > 0:
        e = body >> u32(32 - es)
    else:
        e = jnp.zeros_like(body)
    frac_body = sll(body, i32(es))
    sig = u32(0x80000000) | (frac_body >> u32(1))

    exp = r * i32(1 << es) + e.astype(jnp.int32)

    sig = jnp.where(is_zero | is_nar, u32(0), sig)
    exp = jnp.where(is_zero | is_nar, i32(0), exp)
    sign = jnp.where(is_nar, u32(0), sign)
    return PIR(sign=sign, exp=exp, sig=sig, is_zero=is_zero, is_nar=is_nar)


def encode(sign, exp, sig, sticky, is_zero, is_nar, cfg: PositConfig):
    """PIR -> posit pattern with exact round-to-nearest-even.

    ``sig`` must be normalized (bit 31 set) whenever the value is nonzero.
    Returns a uint32 pattern (low ``nbits`` bits used).
    """
    n, es = cfg.nbits, cfg.es
    sign = u32(sign)
    exp = i32(exp)
    sig = u32(sig)
    sticky = u32(sticky)

    too_big = exp > cfg.max_scale
    too_small = exp < cfg.min_scale
    expc = jnp.clip(exp, cfg.min_scale, cfg.max_scale)

    r = expc >> es if es > 0 else expc       # arithmetic shift: floor div
    e = expc - (r << es) if es > 0 else jnp.zeros_like(expc)

    # regime field (with terminator) as a value + length
    reg_pos = r >= 0
    reg_len = jnp.where(reg_pos, r + 2, 1 - r)          # <= n
    # r >= 0: (r+1) ones then a 0  -> 2^(r+2) - 2 ; r < 0: (-r) zeros then 1
    v_pos = sll(u32(2), r + 1) - u32(2)                 # 2^(r+2) - 2, r+2<=32
    # sll gives 0 when r+2 == 32 => wrap: handle r == 30 case exactly:
    v_pos = jnp.where(r + 2 >= 32, u32(0xFFFFFFFE), v_pos)
    v_reg = jnp.where(reg_pos, v_pos, u32(1))

    stream = u64.shl(u64.from32(v_reg), 64 - reg_len)
    if es > 0:
        stream = u64.bor(stream, u64.shl(u64.from32(u32(e)), 64 - reg_len - es))
    frac31 = sig & u32(0x7FFFFFFF)
    fsh = 33 - reg_len - es  # position of fraction LSB in the stream
    f_in = u64.select(fsh >= 0,
                      u64.shl(u64.from32(frac31), fsh),
                      u64.shr(u64.from32(frac31), -fsh))
    stream = u64.bor(stream, f_in)
    # fraction bits pushed below the stream (fsh < 0) are sticky
    drop_mask = sll(u32(1), -fsh) - u32(1)
    sticky = sticky | jnp.where((fsh < 0) & ((frac31 & drop_mask) != 0),
                                u32(1), u32(0))
    # fold external sticky into bit 0 (strictly below the round position
    # 64-n >= 32 for all n <= 32, so this never corrupts kept bits)
    stream = u64.bor(stream, u64.from32(sticky))

    body = u64.shr(stream, 64 - (n - 1)).lo              # top n-1 bits
    round_bit = u64.bit(stream, 64 - n)
    below = u64.band(stream, u64.sub(u64.shl(u64.from32(u32(1)), 64 - n),
                                     u64.from32(u32(1))))
    sticky_rest = jnp.where((below.hi | below.lo) != 0, u32(1), u32(0))
    inc = round_bit & (sticky_rest | (body & u32(1)))
    p = body + inc

    maxpos = u32(cfg.maxpos_pattern)
    p = jnp.minimum(p, maxpos)                 # never round past maxpos
    p = jnp.maximum(p, u32(1))                 # never round a nonzero to 0
    p = jnp.where(too_big, maxpos, p)
    p = jnp.where(too_small, u32(1), p)        # nonzero tiny -> minpos

    p = jnp.where(sign == 1, (~p + u32(1)) & u32(cfg.mask), p)
    p = jnp.where(is_zero, u32(0), p)
    p = jnp.where(is_nar, u32(cfg.nar_pattern), p)
    return p


def encode_pir(pir: PIR, cfg: PositConfig, sticky=None):
    if sticky is None:
        sticky = jnp.zeros_like(pir.sign)
    return encode(pir.sign, pir.exp, pir.sig, sticky, pir.is_zero,
                  pir.is_nar, cfg)
