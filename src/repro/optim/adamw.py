"""AdamW with global-norm clipping and optional posit16 moment storage.

Posit moment storage is the paper's technique applied to optimizer memory:
the second moment has a huge dynamic range and a tapered-precision profile
(most mass near the small end) — exactly what posit encoding favors.
Stored as uint16 patterns (half the bytes of f32), decoded at update time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.convert import f32_to_posit, posit_to_f32
from repro.core.types import POSIT16


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    posit_moments: bool = False   # store m in posit16 (paper technique)


def _q(x, on):
    return f32_to_posit(x, POSIT16) if on else x


def _dq(x, on):
    return posit_to_f32(x, POSIT16) if on else x


def init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    m = jax.tree.map(lambda p: _q(zeros(p), cfg.posit_moments), params)
    v = jax.tree.map(zeros, params)
    return {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state, params, cfg: AdamWConfig,
           lr_scale: Optional[jnp.ndarray] = None):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)
    lr = cfg.lr * (lr_scale if lr_scale is not None else 1.0)

    def upd(p, g, m, v):
        m_f = _dq(m, cfg.posit_moments)
        m_new = b1 * m_f + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p_new = p.astype(jnp.float32) * (1 - lr * cfg.weight_decay) \
            - lr * step
        return (p_new.astype(p.dtype), _q(m_new, cfg.posit_moments), v_new)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in flat])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in flat])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm}


def cosine_schedule(step, *, base_lr=1.0, warmup=100, total=10000,
                    min_frac=0.1):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos
