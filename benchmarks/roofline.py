"""Roofline table generator: reads experiments/dryrun/*.json and renders
the EXPERIMENTS.md §Roofline table (per arch x shape x mesh: the three
terms, the dominant bottleneck, MODEL_FLOPS ratio)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(directory=DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def render_markdown(recs, mesh="16x16"):
    lines = [
        "| arch | shape | compute | memory | collective | dominant |"
        " peak GiB/dev | useful-FLOP ratio |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        rf = r["roofline"]
        mem = r.get("memory", {})
        ratio = r.get("useful_flop_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rf['compute_s'])} | "
            f"{_fmt_s(rf['memory_s'])} | {_fmt_s(rf['collective_s'])} | "
            f"**{rf['dominant']}** | "
            f"{mem.get('peak_bytes_per_device', 0) / 2**30:.2f} | "
            f"{ratio:.3f} |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - |")
    return "\n".join(lines)


def run():
    recs = load_records()
    ok = [r for r in recs if r.get("ok")]
    rows = [("roofline_cells_ok", 0.0, f"count={len(ok)}")]
    for r in ok:
        rf = r["roofline"]
        rows.append((
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
            f"dom={rf['dominant']} compute={rf['compute_s']:.3g}s "
            f"mem={rf['memory_s']:.3g}s coll={rf['collective_s']:.3g}s"))
    return rows


if __name__ == "__main__":
    print(render_markdown(load_records()))
