"""Fused elementwise PVU kernels vs the f32 round-trip: throughput table.

For each op (vadd/vsub/vmul/vdiv) x config (posit8e2/posit16e2) x vector
length, times:

* ``fused``     — ``kernels.ops.v*``: one Pallas pass, decode -> PIR
  arith -> encode, patterns in / patterns out;
* ``roundtrip`` — the composition it replaces: ``dequantize`` kernel ->
  f32 op -> ``quantize`` kernel (three passes, two roundings, plus an
  f32 temporary 2-4x the pattern bytes).

Emits ``name,us_per_call,derived`` rows (harness contract); ``derived``
carries the fused/roundtrip speedup and the bit-match rate between the
two paths (expected 1.0 for add/sub/mul — the fused path is exactly
rounded, and the double rounding of the round-trip is innocuous at these
widths — and < 1.0 for div mode='nr3', the paper's ~95.8 % divider).
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.types import POSIT8, POSIT16
from repro.kernels import ops

CFGS = (POSIT8, POSIT16)
# interpret-mode friendly lengths; on real TPU (interpret=False) push
# these to 2^20+ — the fused kernel's advantage grows with size.
LENGTHS = (1 << 12, 1 << 16, 1 << 18)
REPEATS = 3


def _patterns(rng, cfg, n):
    p = rng.integers(0, 2 ** cfg.nbits, size=n, dtype=np.uint64)
    p[p == cfg.nar_pattern] = 1          # keep the sweep NaR-free
    return jnp.asarray(p.astype(np.uint32)).astype(cfg.storage_dtype)


def _time(fn):
    jax.block_until_ready(fn())           # compile + warm cache
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / REPEATS * 1e6


def run():
    rng = np.random.default_rng(123)
    rows = []
    for cfg in CFGS:
        for n in LENGTHS:
            a = _patterns(rng, cfg, n)
            b = _patterns(rng, cfg, n)

            f32_ops = {"vadd": jnp.add, "vsub": jnp.subtract,
                       "vmul": jnp.multiply, "vdiv": jnp.divide}

            def roundtrip(op_name):
                return ops.quantize(
                    f32_ops[op_name](ops.dequantize(a, cfg),
                                     ops.dequantize(b, cfg)), cfg)

            fused_fns = {
                "vadd": lambda: ops.vadd(a, b, cfg),
                "vsub": lambda: ops.vsub(a, b, cfg),
                "vmul": lambda: ops.vmul(a, b, cfg),
                "vdiv": lambda: ops.vdiv(a, b, cfg, mode="nr3"),
            }
            for op_name, fused_fn in fused_fns.items():
                us_fused = _time(fused_fn)
                us_rt = _time(lambda: roundtrip(op_name))
                match = float(
                    (np.asarray(fused_fn()) ==
                     np.asarray(roundtrip(op_name))).mean())
                rows.append((
                    f"ew_{op_name}_{cfg.name}_n{n}", us_fused,
                    f"roundtrip_us={us_rt:.1f} "
                    f"speedup={us_rt / max(us_fused, 1e-9):.2f}x "
                    f"bit_match={match:.4f}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(",".join(str(x) for x in row))
