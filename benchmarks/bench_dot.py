"""§IV-E dot product: K-tiled streaming quire vs monolithic vs round-trip.

For each config x reduction length (256 -> 64k), times three paths over
a (rows, L) batch of posit dots:

* ``tiled``     — ``kernels.ops.dot_rows``: the K-tiled Pallas kernel,
  quire state streamed across MAX_DOT_LENGTH tiles in VMEM scratch,
  one rounding total (any length);
* ``monolithic``— the single-tile kernel (``block_k=L``), only defined
  for L <= MAX_DOT_LENGTH = 4096 — the old cap this PR removed;
* ``roundtrip`` — dequantize -> f32 multiply + sum -> quantize: rounds
  every partial product and the f32 accumulation, so it is the accuracy
  bar the quire path clears.

Emits ``name,us_per_call,derived`` rows (harness contract); ``derived``
carries the tiled/monolithic bit-match (expected 1.0 where both exist),
the tiled-vs-roundtrip match rate, and the roundtrip speed ratio.

``--smoke`` runs two short lengths only — the fast CI lane uses it to
exercise the tiled kernel's interpret-mode path on every PR.
"""
from __future__ import annotations

import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import dot as dot_mod
from repro.core.types import POSIT16
from repro.kernels import ops, posit_dot

CFGS = (POSIT16,)
# interpret-mode friendly batch; on real TPU (interpret=False) scale rows
ROWS = 4
LENGTHS = (256, 1024, 4096, 16384, 65536)
SMOKE_LENGTHS = (256, 8192)        # one single-tile, one multi-tile
REPEATS = 3


def _patterns(rng, cfg, shape):
    p = rng.integers(0, 2 ** cfg.nbits, size=shape, dtype=np.uint64)
    p[p == cfg.nar_pattern] = 1          # keep the sweep NaR-free
    return jnp.asarray(p.astype(np.uint32)).astype(cfg.storage_dtype)


def _time(fn):
    jax.block_until_ready(fn())           # compile + warm cache
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / REPEATS * 1e6


def run(smoke: bool = False):
    rng = np.random.default_rng(321)
    rows = []
    for cfg in CFGS:
        for n in (SMOKE_LENGTHS if smoke else LENGTHS):
            a = _patterns(rng, cfg, (ROWS, n))
            b = _patterns(rng, cfg, (ROWS, n))

            def tiled():
                return ops.dot_rows(a, b, cfg)

            def roundtrip():
                fa = ops.dequantize(a, cfg)
                fb = ops.dequantize(b, cfg)
                return ops.quantize(jnp.sum(fa * fb, axis=-1), cfg)

            us_tiled = _time(tiled)
            us_rt = _time(roundtrip)
            rt_match = float(
                (np.asarray(tiled()) == np.asarray(roundtrip())).mean())
            derived = (f"roundtrip_us={us_rt:.1f} "
                       f"rt_ratio={us_rt / max(us_tiled, 1e-9):.2f}x "
                       f"rt_bit_match={rt_match:.4f}")
            if n <= dot_mod.MAX_DOT_LENGTH:
                def mono():
                    return posit_dot.vpdot_rows(a, b, cfg, block_k=n)
                us_mono = _time(mono)
                mono_match = float(
                    (np.asarray(tiled()) == np.asarray(mono())).mean())
                derived += (f" monolithic_us={us_mono:.1f} "
                            f"mono_bit_match={mono_match:.4f}")
            else:
                derived += " monolithic_us=NA(beyond_old_cap)"
            rows.append((f"dot_{cfg.name}_r{ROWS}_n{n}", us_tiled, derived))
    return rows


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv[1:]
    print("name,us_per_call,derived")
    for row in run(smoke=smoke):
        print(",".join(str(x) for x in row))
