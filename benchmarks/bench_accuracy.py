"""Paper §VI accuracy table: per-op exact-match rate vs the golden model.

Reproduces the verification methodology: quantized first-conv
activations x weights (ResNet-18-shaped, int8-quantized then dequantized
— synthetic stand-in, same recipe), converted to posit32, pushed through
every PVU op, compared bit-exactly against the SoftPosit-semantics golden.

Paper's numbers: add/sub/mul/dot 100 %, div 95.84 %.
"""
from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro.core import vpadd, vpdiv, vpdot, vpmul, vpsub
from repro.core import softposit_ref as ref
from repro.core.types import POSIT32


def paperlike_conv_data(rng, n):
    """int8-quantized conv activations/weights, dequantized (paper §VI)."""
    acts = rng.integers(0, 128, size=n) * 0.02       # post-ReLU activations
    wts = rng.integers(-127, 128, size=n) * 0.005    # first-conv weights
    wts[wts == 0] = 0.005
    return acts, wts


def run(n: int = 2000, seed: int = 42):
    rng = np.random.default_rng(seed)
    va, vb = paperlike_conv_data(rng, n)
    a = np.array([ref.from_float(float(v), POSIT32) for v in va],
                 dtype=np.uint32)
    b = np.array([ref.from_float(float(v), POSIT32) for v in vb],
                 dtype=np.uint32)
    ja, jb = jnp.asarray(a), jnp.asarray(b)

    rows = []
    ops = [
        ("vpadd", lambda: vpadd(ja, jb, POSIT32), ref.add),
        ("vpsub", lambda: vpsub(ja, jb, POSIT32), ref.sub),
        ("vpmul", lambda: vpmul(ja, jb, POSIT32), ref.mul),
        ("vpdiv_nr3", lambda: vpdiv(ja, jb, POSIT32, mode="nr3"), ref.div),
        ("vpdiv_exact", lambda: vpdiv(ja, jb, POSIT32, mode="exact"),
         ref.div),
    ]
    for name, fn, gold_fn in ops:
        t0 = time.perf_counter()
        got = np.asarray(fn()).astype(np.uint32)
        dt = (time.perf_counter() - t0) * 1e6
        want = np.array([gold_fn(int(x), int(y), POSIT32)
                         for x, y in zip(a, b)], dtype=np.uint32)
        acc = float((got == want).mean())
        rows.append((name, dt, f"acc={acc:.4f}"))

    # dot product: 4x4-conv-shaped reductions (Listing 2 of the paper)
    rows_n, length = n // 16, 16
    a2 = a[: rows_n * length].reshape(rows_n, length)
    b2 = b[: rows_n * length].reshape(rows_n, length)
    t0 = time.perf_counter()
    got = np.asarray(vpdot(jnp.asarray(a2), jnp.asarray(b2), POSIT32))
    dt = (time.perf_counter() - t0) * 1e6
    want = np.array([ref.dot(a2[i], b2[i], POSIT32)
                     for i in range(rows_n)], dtype=np.uint32)
    acc = float((got.astype(np.uint32) == want).mean())
    rows.append(("vpdot", dt, f"acc={acc:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
