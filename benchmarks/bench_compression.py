"""Beyond-paper table: posit compression wins at the system level.

* cross-pod gradient sync bytes (f32 all-reduce vs posit16/8 all-gather)
* KV-cache bytes per 32k-context request for each serving arch
* checkpoint bytes with the posit16 payload codec
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.compress.kvcache import cache_bytes
from repro.configs.shapes import SHAPES
from repro.launch import specs


def run():
    rows = []
    # gradient wire bytes for one phi3 layer-equivalent tensor
    g = np.prod((5120, 17920))
    rows.append(("grad_wire_f32", 0.0, f"bytes={int(g * 4):,}"))
    rows.append(("grad_wire_posit16", 0.0,
                 f"bytes={int(g * 2):,} saving=2.0x"))
    rows.append(("grad_wire_posit8", 0.0,
                 f"bytes={int(g):,} saving=4.0x"))

    spec = SHAPES["decode_32k"]
    for arch in ("phi3-medium-14b", "granite-34b", "dbrx-132b",
                 "minicpm3-4b"):
        t0 = time.perf_counter()
        cfg16 = configs.config_for_cell(arch, "decode_32k")
        import dataclasses
        cfg_f = dataclasses.replace(cfg16, kv_posit=None,
                                    weight_posit=None)
        sh_q = specs.cache_shape(cfg16, spec)
        sh_f = specs.cache_shape(cfg_f, spec)
        bytes_q = sum(np.prod(l.shape) * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(sh_q)
                      if hasattr(l, "shape"))
        bytes_f = sum(np.prod(l.shape) * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(sh_f)
                      if hasattr(l, "shape"))
        dt = (time.perf_counter() - t0) * 1e6
        # the no-posit baseline stores KV in the compute dtype (bf16);
        # posit16 matches its bytes (the win is tapered *accuracy* at
        # equal width), posit8 halves them; f32 would be 2x bf16.
        rows.append((f"kvcache_{arch}", dt,
                     f"bf16={int(bytes_f):,}B "
                     f"posit={int(bytes_q):,}B "
                     f"saving_vs_bf16={bytes_f / max(bytes_q, 1):.2f}x "
                     f"saving_vs_f32={2 * bytes_f / max(bytes_q, 1):.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
