"""Paper Figs. 5/6 analogue: DNN inference accuracy, posit vs FP32.

Deep-PeNSieve methodology: train a small MLP classifier in FP32, then run
inference with all weights+activations passed through the posit codec
(posit16 / posit32) and compare top-1 accuracy.  Datasets are synthetic
class-cluster problems of MNIST-like shape (offline container — noted in
DESIGN.md §8); the claim under test is the *relative* ordering
posit32 ~ posit16 ~ FP32 at matched task difficulty.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import quant_dequant
from repro.core.types import POSIT8, POSIT16, POSIT32


def make_dataset(rng, n_class=10, dim=64, n_per=200, spread=1.6):
    centers = rng.standard_normal((n_class, dim)) * 2.0
    xs, ys = [], []
    for c in range(n_class):
        xs.append(centers[c] + rng.standard_normal((n_per, dim)) * spread)
        ys.append(np.full(n_per, c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]


def train_mlp(x, y, hidden=128, steps=300, lr=0.05, seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    dim, n_class = x.shape[1], int(y.max()) + 1
    params = {
        "w1": jax.random.normal(k1, (dim, hidden)) * dim ** -0.5,
        "b1": jnp.zeros(hidden),
        "w2": jax.random.normal(k2, (hidden, n_class)) * hidden ** -0.5,
        "b2": jnp.zeros(n_class),
    }
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def loss_fn(p):
        h = jax.nn.relu(xj @ p["w1"] + p["b1"])
        logits = h @ p["w2"] + p["b2"]
        return -jnp.take_along_axis(
            jax.nn.log_softmax(logits), yj[:, None], 1).mean()

    @jax.jit
    def step(p):
        g = jax.grad(loss_fn)(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    for _ in range(steps):
        params = step(params)
    return params


def accuracy(params, x, y, codec=None):
    q = (lambda t: quant_dequant(t, codec)) if codec else (lambda t: t)
    p = jax.tree.map(q, params)
    h = jax.nn.relu(q(x @ p["w1"] + p["b1"]))
    logits = q(h @ p["w2"] + p["b2"])
    return float((jnp.argmax(logits, -1) == y).mean())


def run(seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = []
    for name, spread in [("easy-mnist-like", 2.5),
                         ("fashion-like", 3.5),
                         ("svhn-like", 4.5),
                         ("cifar-like", 5.5)]:
        x, y = make_dataset(rng, spread=spread)
        n_train = int(0.8 * len(x))
        params = train_mlp(x[:n_train], y[:n_train], seed=seed)
        xt = jnp.asarray(x[n_train:])
        yt = jnp.asarray(y[n_train:])
        t0 = time.perf_counter()
        acc32 = accuracy(params, xt, yt, None)
        accp32 = accuracy(params, xt, yt, POSIT32)
        accp16 = accuracy(params, xt, yt, POSIT16)
        accp8 = accuracy(params, xt, yt, POSIT8)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"dnn_{name}", dt,
                     f"fp32={acc32:.4f} posit32={accp32:.4f} "
                     f"posit16={accp16:.4f} posit8={accp8:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
