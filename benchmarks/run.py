"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (harness contract).
  python -m benchmarks.run            # all
  python -m benchmarks.run accuracy   # one suite
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (bench_accuracy, bench_compression, bench_cost,
                            bench_dnn_accuracy, bench_dot, bench_elementwise,
                            bench_serve, roofline)
    suites = {
        "accuracy": bench_accuracy.run,        # paper §VI table
        "dnn": bench_dnn_accuracy.run,         # paper Figs 5/6
        "cost": bench_cost.run,                # paper Table IV analogue
        "compression": bench_compression.run,  # beyond-paper systems wins
        "elementwise": bench_elementwise.run,  # fused PVU ops vs round-trip
        "dot": bench_dot.run,                  # §IV-E tiled quire sweep
        "serve": bench_serve.run,              # engine prefill/decode tok/s
        "roofline": roofline.run,              # §Roofline summary
    }
    wanted = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in wanted:
        for row in suites[name]():
            print(",".join(str(x) for x in row), flush=True)


if __name__ == "__main__":
    main()
