"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (harness contract).
  python -m benchmarks.run              # all
  python -m benchmarks.run accuracy     # one suite
  python -m benchmarks.run serve --json # also write BENCH_serve.json

``--json`` additionally writes one ``benchmarks/BENCH_<suite>.json``
per suite run (next to this file, regardless of the invoking CWD): the
same rows with the ``derived`` ``key=value`` pairs parsed into a dict
(numbers as numbers), so the perf trajectory — serving tok/s, goodput,
peak cache bytes — is machine-comparable across PRs.

``benchmarks/baselines/BENCH_<suite>.json`` holds the committed
baseline for a suite (seeded from the PR-6 run).  When one exists, each
fresh row is compared against its committed counterpart and a
``# delta vs baseline`` line is printed per matching row — refresh the
baseline by copying the new ``BENCH_<suite>.json`` over it whenever a
PR intentionally moves the numbers.
"""
from __future__ import annotations

import json
import os
import sys


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in str(derived).split():
        if "=" not in part:
            out.setdefault("notes", []).append(part)
            continue
        k, v = part.split("=", 1)
        num = v[:-1] if v.endswith("x") else v
        try:
            out[k] = int(num)
        except ValueError:
            try:
                out[k] = float(num)
            except ValueError:
                out[k] = v
    return out


def _write_json(suite: str, rows) -> str:
    # artifacts land next to this file, never in the invoking CWD (a
    # repo-root BENCH_*.json was an easy stray to commit); committed
    # baselines live one level deeper in benchmarks/baselines/
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        f"BENCH_{suite}.json")
    payload = [
        {"name": name, "us_per_call": float(us),
         "derived": _parse_derived(derived)}
        for name, us, derived in rows
    ]
    with open(path, "w") as f:
        json.dump({"suite": suite, "rows": payload}, f, indent=2)
        f.write("\n")
    return path


def _print_deltas(suite: str, rows, baselines_dir: str = None) -> None:
    """Compare fresh rows against ``benchmarks/baselines/BENCH_<suite>.json``
    (committed baseline) and print a ``# delta vs baseline`` line per
    matching row name.  A suite with no committed baseline says so
    explicitly (it used to skip silently, which read as "no change"
    when it meant "nothing to compare against"); corrupt baselines
    warn and skip."""
    if baselines_dir is None:
        baselines_dir = os.path.join(
            os.path.dirname(os.path.abspath(__file__)), "baselines")
    path = os.path.join(baselines_dir, f"BENCH_{suite}.json")
    if not os.path.exists(path):
        print(f"# {suite}: no committed baseline "
              f"(benchmarks/baselines/BENCH_{suite}.json missing; run "
              f"'python -m benchmarks.run {suite} --json' and copy "
              f"benchmarks/BENCH_{suite}.json there to start tracking "
              "deltas)", file=sys.stderr, flush=True)
        return
    try:
        with open(path) as f:
            base = {r["name"]: r for r in json.load(f)["rows"]}
    except (OSError, ValueError, KeyError) as e:  # corrupt baseline: warn
        print(f"# baseline {path} unreadable: {e}", file=sys.stderr)
        return
    for name, us, derived in rows:
        ref = base.get(str(name))
        if ref is None:
            print(f"# {name}: new row (no baseline)", file=sys.stderr)
            continue
        parts = []
        b_us = float(ref.get("us_per_call", 0.0))
        if b_us > 0:
            parts.append(f"us_per_call {(float(us) - b_us) / b_us:+.1%}")
        fresh = _parse_derived(derived)
        for k, bv in ref.get("derived", {}).items():
            fv = fresh.get(k)
            if not isinstance(bv, (int, float)) or isinstance(bv, bool):
                continue
            if not isinstance(fv, (int, float)) or isinstance(fv, bool):
                continue
            if fv == bv:
                continue
            if bv != 0:
                parts.append(f"{k} {bv:g}->{fv:g} ({(fv - bv) / bv:+.1%})")
            else:
                parts.append(f"{k} {bv:g}->{fv:g}")
        if parts:
            print(f"# {name} delta vs baseline: " + " ".join(parts),
                  file=sys.stderr, flush=True)


def main() -> None:
    from benchmarks import (bench_accuracy, bench_compression, bench_cost,
                            bench_dnn_accuracy, bench_dot, bench_elementwise,
                            bench_serve, roofline)
    suites = {
        "accuracy": bench_accuracy.run,        # paper §VI table
        "dnn": bench_dnn_accuracy.run,         # paper Figs 5/6
        "cost": bench_cost.run,                # paper Table IV analogue
        "compression": bench_compression.run,  # beyond-paper systems wins
        "elementwise": bench_elementwise.run,  # fused PVU ops vs round-trip
        "dot": bench_dot.run,                  # §IV-E tiled quire sweep
        "serve": bench_serve.run,              # engine tok/s + paged cache
        "roofline": roofline.run,              # §Roofline summary
    }
    args = sys.argv[1:]
    as_json = "--json" in args
    wanted = [a for a in args if a != "--json"] or list(suites)
    print("name,us_per_call,derived")
    for name in wanted:
        rows = list(suites[name]())
        for row in rows:
            print(",".join(str(x) for x in row), flush=True)
        _print_deltas(name, rows)
        if as_json:
            path = _write_json(name, rows)
            print(f"# wrote {path}", file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
