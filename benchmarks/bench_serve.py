"""Serving engine throughput: prefill + scan-decode tok/s by KV format.

For each KV-cache storage format (f32 ``none``, ``posit16``, ``posit8``)
on a reduced transformer config, times the engine's jitted prefill and
its single-``lax.scan`` decode, and compares the scan against the
per-step jitted Python loop (dispatch overhead) once for the f32 cache.

Emits ``name,us_per_call,derived`` rows (harness contract); ``derived``
carries decode tok/s, the cache compression ratio, and the
scan-vs-stepwise token agreement (expected 1.0 — the regression guard
that one-jit decode matches the reference loop).

``--smoke`` shrinks the sweep for the CI fast lane (exercises prefill
headroom, ring-free dense decode, and both posit codecs end to end).
"""
from __future__ import annotations

import dataclasses
import sys
import time

import numpy as np

import jax

from repro import configs
from repro.compress.kvcache import cache_report
from repro.models import get_family
from repro.runtime.engine import Engine

ARCH = "phi3-medium-14b"
KV_FORMATS = (None, "posit16", "posit8")
REPEATS = 3


def _time(fn):
    jax.block_until_ready(fn())           # compile + warm cache
    t0 = time.perf_counter()
    out = None
    for _ in range(REPEATS):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / REPEATS * 1e6


def run(smoke: bool = False):
    batch, prompt_len, gen = (2, 16, 8) if smoke else (4, 32, 32)
    base = configs.get_config(ARCH).reduced(compute_dtype="float32")
    rng = np.random.default_rng(7)
    params = get_family(base).init_params(jax.random.PRNGKey(0), base)
    prompts = rng.integers(1, base.vocab, size=(batch, prompt_len))

    rows = []
    stepwise_tokens = None
    for kv in KV_FORMATS:
        cfg = dataclasses.replace(base, kv_posit=kv)
        eng = Engine(cfg, params, max_len=prompt_len + gen, seed=0)

        us_prefill = _time(lambda: eng.prefill(prompts)[1])
        cache, _, _ = eng.prefill(prompts)
        rep = cache_report(cache)
        rows.append((f"serve_prefill_kv={kv or 'none'}_b{batch}"
                     f"_s{prompt_len}", us_prefill,
                     f"cache_bytes={rep['bytes']} "
                     f"ratio={rep['ratio']:.2f}x"))

        us_gen = _time(lambda: eng.generate(prompts, gen).tokens)
        tok_s = gen * batch / (us_gen / 1e6)
        derived = f"tok_s={tok_s:.1f} gen={gen}"
        if kv is None:
            # dispatch-overhead reference: per-step jitted Python loop
            us_step = _time(
                lambda: eng.generate_stepwise(prompts, gen).tokens)
            agree = float((eng.generate(prompts, gen).tokens ==
                           eng.generate_stepwise(prompts, gen).tokens)
                          .mean())
            stepwise_tokens = agree
            derived += (f" stepwise_us={us_step:.1f} "
                        f"scan_speedup={us_step / max(us_gen, 1e-9):.2f}x "
                        f"scan_vs_step_match={agree:.4f}")
        rows.append((f"serve_decode_kv={kv or 'none'}_b{batch}"
                     f"_g{gen}", us_gen, derived))
    assert stepwise_tokens == 1.0, \
        "scan decode diverged from the per-step reference loop"
    return rows


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv[1:]
    print("name,us_per_call,derived")
    for row in run(smoke=smoke):
        print(",".join(str(x) for x in row))
