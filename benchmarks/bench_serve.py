"""Serving engine throughput: prefill + scan-decode tok/s by KV format,
plus continuous-vs-static batching goodput on a ragged arrival trace.

For each KV-cache storage format (f32 ``none``, ``posit16``, ``posit8``)
on a reduced transformer config, times the engine's jitted prefill and
its single-``lax.scan`` decode, and compares the scan against the
per-step jitted Python loop (dispatch overhead) once for the f32 cache.

The continuous-batching section replays the SAME Poisson trace (ragged
prompt and generation lengths) through (a) static batching — groups of
``n_slots`` requests that prefill together once the whole group has
arrived and decode ``max(gen)`` steps for everyone — and (b) the
iteration-level scheduler, which retires rows at EOS/max-tokens and
admits queued prompts between fixed-size decode chunks.  Both serve the
same useful-token demand on the same simulated clock (1 tick = 1 decode
step; batch-formation waits and arrival gaps tick too), so goodput =
useful tokens / makespan compares what a user actually sees; the run
asserts continuous wins.  Executed-step utilization is also reported —
static can look "efficient" there precisely because its requests sit in
queues instead of slots.

Emits ``name,us_per_call,derived`` rows (harness contract); ``derived``
carries decode tok/s, the cache compression ratio, the scan-vs-stepwise
token agreement (expected 1.0), and for the batching comparison the
goodput and p50/p99 request latency in decode steps.

The paged-cache section replays the same trace through the compaction
scheduler and the paged (block-table) scheduler: pass 1 sizes the block
arena from the trace's committed-blocks high-water mark, pass 2 reruns
on that right-sized arena and asserts token/schedule identity with
strictly fewer peak cache bytes than the dense ``slots x max_len`` pool.
Pass 3 reruns the right-sized arena decoding through the FUSED Pallas
paged-attention kernel (``kernels/posit_paged_attn.py``) and asserts
token/schedule identity again, plus — the ROADMAP's decode-bytes ask —
reports analytic decode KV bytes/token for both paths and asserts the
fused kernel moves strictly fewer bytes than gather+dequant.

The prefix-caching section replays a SHARED-prefix trace (every prompt
opens with the same system prefix) through the paged scheduler with and
without ``prefix_cache=True`` and asserts token identity with strictly
fewer prefill tokens and strictly fewer peak physical blocks — the
dedup win, dropping roughly with the share ratio.

The chunked-prefill section replays an OVERLOAD Poisson trace (arrival
rate far above drain capacity, ragged prompt lengths) through the paged
scheduler with and without ``chunked_prefill=True`` on all three
attention lanes (dense, MLA, sliding-window).  The unchunked path
jit-specializes admission prefill per prompt length, so every novel
length stalls the whole pool behind a compile; chunked mode serves
every request through ONE compiled ``mixed_step`` shape.  Both passes
must be token-identical per request; the chunked pass must hold a FLAT
engine compile count after warmup and beat the unchunked pass on p99
WALL-CLOCK request latency — the tail a recompile stall actually
inflates (simulation-clock latency alone cannot see it).

The sharded section replays one chunked-prefill paged trace through a
single-device engine and a tensor-parallel engine over a host device
mesh (weights by the ``runtime/sharding.py`` rule table, the KV block
arena head-sharded over 'model') and asserts per-request token AND
schedule identity — sharding must be invisible to the trace — plus the
point of the exercise: each device holds ~1/mp of the arena content
bytes, within one block of slack.  Needs >= 2 devices; on CPU force
them with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--smoke`` shrinks the sweep for the CI fast lane (exercises prefill
headroom, ring-free dense decode, both posit codecs, and the
continuous-batching scheduler end to end); ``--paged`` runs ONLY the
paged-vs-compaction comparison (the fast lane's paged smoke),
``--prefix-share`` adds (or alone, runs only) the prefix-caching
comparison, ``--chunked`` runs ONLY the chunked-prefill comparison,
and ``--sharded`` runs ONLY the tensor-parallel comparison.
``--sanitize`` arms the arena sanitizer on the paged, prefix, chunked
and sharded passes (``BlockPool(sanitize=True)`` misuse checks,
pre-chunk write gates, poisoned reclaims) and asserts the traces end
leak-free — the CI smoke runs with it so every PR replays the serving
trace under the sanitizer.
"""
from __future__ import annotations

import dataclasses
import math
import sys
import time

import numpy as np

import jax

from repro import configs
from repro.compress.kvcache import cache_report
from repro.launch.serve import (drive_trace, poisson_trace,
                                shared_prefix_trace)
from repro.models import get_family
from repro.runtime.engine import Engine
from repro.runtime.scheduler import Scheduler

ARCH = "phi3-medium-14b"
KV_FORMATS = (None, "posit16", "posit8")
REPEATS = 3


def _time(fn):
    jax.block_until_ready(fn())           # compile + warm cache
    t0 = time.perf_counter()
    out = None
    for _ in range(REPEATS):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / REPEATS * 1e6


def run(smoke: bool = False, paged: bool = True):
    batch, prompt_len, gen = (2, 16, 8) if smoke else (4, 32, 32)
    base = configs.get_config(ARCH).reduced(compute_dtype="float32")
    rng = np.random.default_rng(7)
    params = get_family(base).init_params(jax.random.PRNGKey(0), base)
    prompts = rng.integers(1, base.vocab, size=(batch, prompt_len))

    rows = []
    stepwise_tokens = None
    for kv in KV_FORMATS:
        cfg = dataclasses.replace(base, kv_posit=kv)
        eng = Engine(cfg, params, max_len=prompt_len + gen, seed=0)

        us_prefill = _time(lambda: eng.prefill(prompts)[1])
        cache, _, _ = eng.prefill(prompts)
        rep = cache_report(cache)
        rows.append((f"serve_prefill_kv={kv or 'none'}_b{batch}"
                     f"_s{prompt_len}", us_prefill,
                     f"cache_bytes={rep['bytes']} "
                     f"ratio={rep['ratio']:.2f}x"))

        us_gen = _time(lambda: eng.generate(prompts, gen).tokens)
        tok_s = gen * batch / (us_gen / 1e6)
        derived = f"tok_s={tok_s:.1f} gen={gen}"
        if kv is None:
            # dispatch-overhead reference: per-step jitted Python loop
            us_step = _time(
                lambda: eng.generate_stepwise(prompts, gen).tokens)
            agree = float((eng.generate(prompts, gen).tokens ==
                           eng.generate_stepwise(prompts, gen).tokens)
                          .mean())
            stepwise_tokens = agree
            derived += (f" stepwise_us={us_step:.1f} "
                        f"scan_speedup={us_step / max(us_gen, 1e-9):.2f}x "
                        f"scan_vs_step_match={agree:.4f}")
        rows.append((f"serve_decode_kv={kv or 'none'}_b{batch}"
                     f"_g{gen}", us_gen, derived))
    assert stepwise_tokens == 1.0, \
        "scan decode diverged from the per-step reference loop"
    rows.extend(run_batching_comparison(smoke=smoke))
    if paged:
        rows.extend(run_paged_comparison(smoke=smoke))
        rows.extend(run_prefix_comparison(smoke=smoke))
        rows.extend(run_chunked_comparison(smoke=smoke))
        rows.extend(run_sharded_comparison(smoke=smoke))
    return rows


def _static_batching(cfg, params, trace, n_slots, max_len):
    """Static batching baseline: requests group in arrival order, a group
    prefills only once its LAST member has arrived, and every row decodes
    ``max(gen)`` steps — the padding/idle waste continuous batching
    removes.  Returns (useful_tokens, executed_steps, latencies,
    makespan_steps, wall_s).
    """
    eng = Engine(cfg, params, max_len=max_len, seed=0)
    clock = 0.0                      # decode-step simulation clock
    useful, steps, lats = 0, 0, []
    t0 = time.perf_counter()
    for i in range(0, len(trace), n_slots):
        group = trace[i:i + n_slots]
        start = max(clock, max(t for t, _, _ in group))
        gen_max = max(g for _, _, g in group)
        eng.generate([p for _, p, _ in group], gen_max)
        steps += gen_max
        clock = start + gen_max
        for t, _, g in group:
            useful += g              # only the requested tokens count
            lats.append(clock - t)
    return useful, steps, lats, clock, time.perf_counter() - t0


def run_batching_comparison(smoke: bool = False):
    """Continuous vs static batching on one ragged Poisson trace."""
    # arrival rates chosen to keep the pool under load (arrivals at or
    # above drain capacity): an idle pool makes every scheduler look the
    # same because the makespan is arrival-tail-bound, not service-bound
    # chunk size trades scheduling overhead against retirement/admission
    # granularity: a finished row overshoots by up to chunk-1 steps, so
    # big chunks erode the win on short ragged generations
    if smoke:
        n_req, n_slots, plen, gen, chunk, rate = 8, 2, 8, 8, 4, 1.0
    else:
        n_req, n_slots, plen, gen, chunk, rate = 24, 4, 16, 16, 4, 1.2
    max_len = plen + gen - 1 + chunk
    cfg = configs.get_config(ARCH).reduced(compute_dtype="float32")
    params = get_family(cfg).init_params(jax.random.PRNGKey(0), cfg)
    trace = poisson_trace(np.random.default_rng(11), n_req, rate,
                          cfg.vocab, plen, gen)

    s_useful, s_steps, s_lat, s_makespan, s_wall = _static_batching(
        cfg, params, trace, n_slots, max_len)
    s_goodput = s_useful / max(s_makespan, 1e-9)

    eng = Engine(cfg, params, max_len=max_len, seed=0)
    sched = Scheduler(eng, n_slots=n_slots, chunk_size=chunk)
    t0 = time.perf_counter()
    done, _ = drive_trace(sched, trace)
    c_wall = time.perf_counter() - t0
    c_useful = sum(len(c.tokens) for c in done.values())
    c_steps = sched.n_chunks * sched.chunk_size
    c_makespan = max(c.finished_step for c in done.values())
    c_goodput = c_useful / max(c_makespan, 1e-9)
    c_lat = [c.latency_steps for c in done.values()]

    rows = [
        (f"serve_static_batch_b{n_slots}_n{n_req}", s_wall * 1e6,
         f"goodput_tok_per_step={s_goodput:.2f} "
         f"useful={s_useful} makespan={s_makespan:.0f} "
         f"util={s_useful / (s_steps * n_slots):.2f} "
         f"lat_p50={np.percentile(s_lat, 50):.0f} "
         f"lat_p99={np.percentile(s_lat, 99):.0f}"),
        (f"serve_continuous_b{n_slots}_n{n_req}_c{chunk}", c_wall * 1e6,
         f"goodput_tok_per_step={c_goodput:.2f} "
         f"useful={c_useful} makespan={c_makespan} "
         f"util={c_useful / (c_steps * n_slots):.2f} "
         f"lat_p50={np.percentile(c_lat, 50):.0f} "
         f"lat_p99={np.percentile(c_lat, 99):.0f} "
         f"goodput_gain={c_goodput / max(s_goodput, 1e-9):.2f}x"),
    ]
    assert c_useful == s_useful, \
        "the two batching modes served different token demand"
    assert c_goodput > s_goodput, (
        f"continuous batching goodput {c_goodput:.3f} tok/step did not "
        f"beat static batching {s_goodput:.3f} on the ragged trace")
    return rows


def run_paged_comparison(smoke: bool = False, sanitize: bool = False):
    """Paged (block-table) vs compaction scheduler on one ragged trace.

    Two paged passes: the first (worst-case arena, no deferrals
    possible) measures the trace's committed-blocks high-water mark;
    the second replays on an arena of exactly that size — reservations
    still never defer, so scheduling is identical — and must match the
    compaction scheduler's completions token for token and step for
    step on strictly fewer cache bytes than ``slots x max_len``.
    """
    if smoke:
        n_req, n_slots, plen, gen, chunk, rate = 8, 2, 8, 8, 4, 1.0
    else:
        n_req, n_slots, plen, gen, chunk, rate = 24, 4, 16, 16, 4, 1.2
    block = 4
    # the dense pool must budget max_len for the WORST request (plus
    # chunk overshoot) with slack for anything longer; paged rows only
    # ever commit their own actual need, so with >= 2 blocks of dense
    # slack the byte win below holds for ANY trace, not by seed luck
    max_len = plen + gen - 1 + chunk + 2 * block
    cfg = configs.get_config(ARCH).reduced(compute_dtype="float32")
    params = get_family(cfg).init_params(jax.random.PRNGKey(0), cfg)
    trace = poisson_trace(np.random.default_rng(11), n_req, rate,
                          cfg.vocab, plen, gen)

    lin = Scheduler(Engine(cfg, params, max_len=max_len, seed=0),
                    n_slots=n_slots, chunk_size=chunk)
    t0 = time.perf_counter()
    done_l, _ = drive_trace(lin, trace)
    l_wall = time.perf_counter() - t0
    l_bytes = cache_report(lin.cache)["bytes"]

    # pass 1: worst-case arena -> the trace's committed-block peak
    probe = Scheduler(Engine(cfg, params, max_len=max_len, seed=0,
                             paged=True, block_size=block),
                      n_slots=n_slots, chunk_size=chunk)
    drive_trace(probe, trace)
    n_blocks = probe.peak_committed

    # pass 2: right-sized arena (identical scheduling, fewer bytes);
    # --sanitize arms the arena sanitizer here, asserting the trace is
    # leak-free under the tightest pool the trace admits
    pag = Scheduler(Engine(cfg, params, max_len=max_len, seed=0,
                           paged=True, block_size=block,
                           n_blocks=n_blocks, sanitize=sanitize),
                    n_slots=n_slots, chunk_size=chunk)
    t0 = time.perf_counter()
    done_p, _ = drive_trace(pag, trace)
    p_wall = time.perf_counter() - t0
    p_bytes = cache_report(pag.cache)["bytes"]
    if sanitize:
        assert pag.n_leaked == 0 and not pag.leak_report(), \
            f"sanitizer found leaked arena blocks: {pag.leak_report()}"

    # pass 3: same right-sized arena, decoding through the FUSED Pallas
    # paged-attention kernel (block-table walk, posit decode in-kernel,
    # online softmax in VMEM) — must be token/schedule-identical to the
    # gather path while moving strictly fewer KV bytes per decoded token
    fus = Scheduler(Engine(cfg, params, max_len=max_len, seed=0,
                           paged=True, block_size=block,
                           n_blocks=n_blocks, sanitize=sanitize,
                           decode_kernel="fused"),
                    n_slots=n_slots, chunk_size=chunk)
    t0 = time.perf_counter()
    done_f, _ = drive_trace(fus, trace)
    f_wall = time.perf_counter() - t0

    assert done_l.keys() == done_p.keys() == done_f.keys()
    for rid in done_l:
        assert (done_p[rid].tokens == done_l[rid].tokens).all(), \
            f"paged scheduler diverged from compaction on request {rid}"
        assert done_p[rid].finished_step == done_l[rid].finished_step
        assert (done_f[rid].tokens == done_p[rid].tokens).all(), \
            f"fused paged decode diverged from gather on request {rid}"
        assert done_f[rid].finished_step == done_p[rid].finished_step
    # per-request identity above implies useful tokens, makespan and
    # therefore goodput are EXACTLY equal — "no goodput regression" is
    # the identity check; only wall-clock can differ between the two
    useful = sum(len(c.tokens) for c in done_p.values())
    makespan = max(c.finished_step for c in done_p.values())
    goodput = useful / max(makespan, 1e-9)
    assert p_bytes < l_bytes, (
        f"paged arena ({p_bytes} B) not smaller than the dense "
        f"slots x max_len pool ({l_bytes} B)")
    dense_blocks = n_slots * pag.table_width

    # decode bytes/token ledger (ROADMAP: report alongside tok/s): the
    # fused kernel reads KV patterns from HBM once; the gather path
    # reads the arena, round-trips the gathered copy and (for posit KV)
    # the dequantized cache on top.  The CI smoke gates the strict win
    # for the serving config AND the posit16 KV cache it exists for.
    from repro.kernels.posit_paged_attn import paged_decode_kv_bytes
    tw, bs = pag.table_width, block
    bytes_parts = []
    for kv in (cfg.kv_posit, "posit16"):
        kcfg = dataclasses.replace(cfg, kv_posit=kv)
        b_f = paged_decode_kv_bytes(kcfg, tw, bs, kernel="fused")
        b_g = paged_decode_kv_bytes(kcfg, tw, bs, kernel="gather")
        assert b_f < b_g, (
            f"fused paged decode must move strictly fewer KV bytes than "
            f"gather+dequant (kv={kv}: {b_f} vs {b_g})")
        tag = kv or "none"
        bytes_parts.append(f"decode_kv_B_tok_fused_{tag}={b_f} "
                           f"decode_kv_B_tok_gather_{tag}={b_g} "
                           f"decode_bytes_saved_{tag}="
                           f"{1 - b_f / b_g:.2f}")
    return [
        (f"serve_paged_b{n_slots}_n{n_req}_c{chunk}_blk{block}",
         p_wall * 1e6,
         f"goodput_tok_per_step={goodput:.2f} "
         f"peak_cache_bytes={p_bytes} dense_cache_bytes={l_bytes} "
         f"bytes_saved={1 - p_bytes / l_bytes:.2f} "
         f"arena_blocks={n_blocks} worst_case_blocks={dense_blocks} "
         f"peak_blocks_in_use={pag.pool.peak_in_use} "
         f"wall_vs_compaction={p_wall / max(l_wall, 1e-9):.2f}x"),
        (f"serve_paged_fused_b{n_slots}_n{n_req}_c{chunk}_blk{block}",
         f_wall * 1e6,
         f"tokens_match_gather=1.0 " + " ".join(bytes_parts) + " "
         f"wall_vs_gather={f_wall / max(p_wall, 1e-9):.2f}x"),
    ]


def run_prefix_comparison(smoke: bool = False, sanitize: bool = False):
    """Prefix caching vs plain paging on a shared-prefix trace.

    Every prompt opens with the same system prefix (share ratio ~0.75),
    the regime prefix caching is built for.  The prefix-cached pass must
    reproduce the non-sharing paged pass token for token while
    prefilling strictly fewer tokens and committing strictly fewer peak
    PHYSICAL blocks — both dropping roughly with the share ratio (the
    matched prefix is stored once instead of once per resident sharer).

    Both passes first run a WARM-UP donor request (the bare system
    prefix) to completion before the timed trace.  Chunked admission
    registers a prompt's blocks only once its prefill finishes, so a
    cold index plus a dense arrival burst means the first ``n_slots``
    requests all prefill concurrently with nothing to share — and
    since ``peak_committed`` is a trace-wide max, that cold-start burst
    would pin both passes to the same worst-case peak and hide the
    steady-state win this benchmark exists to measure.  The donor makes
    the prefix resident (index-held, evictable) up front, which is the
    serving regime the docstring above describes.
    """
    if smoke:
        n_req, n_slots, plen, gen, chunk, rate = 8, 2, 16, 8, 4, 1.0
    else:
        n_req, n_slots, plen, gen, chunk, rate = 24, 4, 32, 16, 4, 1.2
    block, share = 4, 0.75
    max_len = plen + gen - 1 + chunk
    cfg = configs.get_config(ARCH).reduced(compute_dtype="float32")
    params = get_family(cfg).init_params(jax.random.PRNGKey(0), cfg)
    trace = shared_prefix_trace(np.random.default_rng(13), n_req, rate,
                                cfg.vocab, plen, gen, share=share)
    # the donor prompt is exactly the shared system prefix (same
    # formula as shared_prefix_trace's n_shared)
    donor = trace[0][1][:max(1, int(plen * share))]

    def _warm(sched):
        donor_rid = sched.submit(list(donor), 1)
        while sched.has_work:
            sched.step()
        sched.steps_run = 0            # replay arrivals as authored
        return donor_rid

    base = Scheduler(Engine(cfg, params, max_len=max_len, seed=0,
                            paged=True, block_size=block),
                     n_slots=n_slots, chunk_size=chunk)
    _warm(base)
    t0 = time.perf_counter()
    done_b, _ = drive_trace(base, trace)
    b_wall = time.perf_counter() - t0

    # --sanitize arms the arena sanitizer on the sharing pass (the one
    # with COW/refcount invariants to violate) and asserts leak-freedom
    pfx = Scheduler(Engine(cfg, params, max_len=max_len, seed=0,
                           paged=True, block_size=block,
                           sanitize=sanitize),
                    n_slots=n_slots, chunk_size=chunk, prefix_cache=True)
    _warm(pfx)
    t0 = time.perf_counter()
    done_p, _ = drive_trace(pfx, trace)
    p_wall = time.perf_counter() - t0
    if sanitize:
        assert pfx.n_leaked == 0 and not pfx.leak_report(), \
            f"sanitizer found leaked arena blocks: {pfx.leak_report()}"

    assert done_b.keys() == done_p.keys()
    for rid in done_b:
        assert (done_p[rid].tokens == done_b[rid].tokens).all(), \
            f"prefix caching changed the tokens of request {rid}"
    assert pfx.prefix_hits > 0, "shared-prefix trace produced no hits"
    assert pfx.prefill_tokens < base.prefill_tokens, (
        f"prefix caching did not cut prefill work "
        f"({pfx.prefill_tokens} vs {base.prefill_tokens} tokens)")
    assert pfx.peak_committed < base.peak_committed, (
        f"prefix caching did not cut peak physical blocks "
        f"({pfx.peak_committed} vs {base.peak_committed})")
    return [
        (f"serve_prefix_b{n_slots}_n{n_req}_share{share}",
         p_wall * 1e6,
         f"prefill_tokens={pfx.prefill_tokens} "
         f"baseline_prefill_tokens={base.prefill_tokens} "
         f"prefill_saved={1 - pfx.prefill_tokens / base.prefill_tokens:.2f} "
         f"peak_physical_blocks={pfx.peak_committed} "
         f"baseline_peak_blocks={base.peak_committed} "
         f"peak_logical_blocks={pfx.peak_logical} "
         f"prefix_hits={pfx.prefix_hits} cow_copies={pfx.n_cow} "
         f"evictions={pfx.n_evicted} "
         f"wall_vs_paged={p_wall / max(b_wall, 1e-9):.2f}x"),
    ]


def _lane_cfg(lane):
    if lane == "mla":
        return configs.get_config("minicpm3-4b").reduced(
            compute_dtype="float32")
    cfg = configs.get_config(ARCH).reduced(compute_dtype="float32")
    if lane == "window":
        cfg = dataclasses.replace(cfg, sliding_window=8, attn_chunk_kv=8)
    return cfg


def _drive_wall(sched, trace):
    """Like :func:`drive_trace` but records each request's WALL-CLOCK
    latency (submit -> completion), the number a compile stall actually
    inflates; returns ``(done, {rid: seconds})``."""
    pending = list(trace)
    done, t_sub, lat = {}, {}, {}
    while pending or sched.has_work:
        while pending and pending[0][0] <= sched.steps_run:
            _, prompt, gen = pending.pop(0)
            rid = sched.submit(prompt, gen)
            t_sub[rid] = time.perf_counter()
        if not sched.has_work:
            sched.steps_run = max(sched.steps_run,
                                  int(np.ceil(pending[0][0])))
            continue
        for c in sched.step():
            done[c.rid] = c
            lat[c.rid] = time.perf_counter() - t_sub[c.rid]
    return done, lat


def run_chunked_comparison(smoke: bool = False, sanitize: bool = False):
    """Chunked vs whole-prompt prefill under an overload Poisson trace,
    on all three attention lanes.

    The arrival rate is far above drain capacity, so the pool is
    saturated and every admission stall lands on someone's tail
    latency.  The trace's ragged prompt lengths make the unchunked
    admission path compile one prefill per novel length; the chunked
    pass serves them all through the warm ``mixed_step`` program.
    Asserts per-request token identity, a flat post-warmup compile
    count (without the sanitizer, whose poison dispatches legitimately
    jit per reclaim size), and a chunked p99 wall-latency win.
    """
    if smoke:
        n_req, n_slots, plen, gen, chunk = 8, 2, 12, 6, 4
    else:
        n_req, n_slots, plen, gen, chunk = 16, 4, 24, 12, 4
    block, rate = 4, 4.0               # rate >> drain: overload regime
    max_len = plen + gen - 1 + chunk
    rows = []
    for lane in ("dense", "mla", "window"):
        cfg = _lane_cfg(lane)
        params = get_family(cfg).init_params(jax.random.PRNGKey(0), cfg)
        trace = poisson_trace(np.random.default_rng(17), n_req, rate,
                              cfg.vocab, plen, gen)
        warm = [(0.0, list(range(1, chunk + 2)), 2)]  # compile warmup

        results = {}
        for mode in ("unchunked", "chunked"):
            eng = Engine(cfg, params, max_len=max_len, seed=0,
                         paged=True, block_size=block,
                         sanitize=sanitize and mode == "chunked")
            sched = Scheduler(eng, n_slots=n_slots, chunk_size=chunk,
                              chunked_prefill=mode == "chunked")
            _drive_wall(sched, warm)   # exclude warmup compiles
            warm_compiles = eng.n_compiles
            done, lat = _drive_wall(sched, trace)
            results[mode] = (done, lat, warm_compiles, eng, sched)

        done_u, lat_u, _, eng_u, _ = results["unchunked"]
        done_c, lat_c, warm_c, eng_c, sched_c = results["chunked"]
        ids = [r for r in done_u if r in lat_u]
        assert done_u.keys() == done_c.keys()
        for rid in done_u:
            assert (done_u[rid].tokens == done_c[rid].tokens).all(), \
                f"chunked prefill changed the tokens of request {rid}"
        if not sanitize:
            assert eng_c.n_compiles == warm_c, (
                f"chunked engine compiled "
                f"{eng_c.n_compiles - warm_c} new programs after "
                f"warmup on the {lane} lane")
        if sanitize:
            assert sched_c.n_leaked == 0 and not sched_c.leak_report()
        p99_u = float(np.percentile([lat_u[r] for r in ids], 99))
        p99_c = float(np.percentile([lat_c[r] for r in ids], 99))
        assert p99_c < p99_u, (
            f"chunked prefill p99 wall latency {p99_c * 1e3:.0f} ms did "
            f"not beat unchunked {p99_u * 1e3:.0f} ms on the {lane} "
            f"lane (overload trace)")
        rows.append((
            f"serve_chunked_{lane}_b{n_slots}_n{n_req}_c{chunk}",
            p99_c * 1e6,
            f"p99_wall_ms={p99_c * 1e3:.1f} "
            f"unchunked_p99_wall_ms={p99_u * 1e3:.1f} "
            f"p99_speedup={p99_u / max(p99_c, 1e-9):.2f}x "
            f"compiles={eng_c.n_compiles} "
            f"unchunked_compiles={eng_u.n_compiles}"))
    return rows


def run_sharded_comparison(smoke: bool = False, sanitize: bool = False):
    """Tensor-parallel vs single-device serving on one paged trace.

    Builds a host mesh over all local devices with the largest 'model'
    degree dividing both the device count and the config's KV heads,
    replays the SAME chunked-prefill paged trace through a
    single-device engine and a mesh engine (weights by the
    ``runtime/sharding.py`` rule table, arena heads on 'model'), and
    asserts per-request token AND schedule identity — sharding the
    arena must be invisible to the trace.  The byte ledger then gates
    the point of the exercise: each device's arena CONTENT footprint is
    at most ``content / mp`` plus one block of slack (replicated
    block-table metadata rides on every shard and is accounted
    separately).  Needs >= 2 devices (on CPU force them with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``); returns no
    rows otherwise, which the baseline delta machinery tolerates.
    """
    n_dev = len(jax.devices())
    heads = 4                          # a head count mp can divide
    mp = math.gcd(n_dev, heads)
    if mp < 2:
        print(f"# serve_sharded: skipped ({n_dev} device(s); force "
              "more with XLA_FLAGS=--xla_force_host_platform_device_"
              "count=8)", file=sys.stderr, flush=True)
        return []
    if smoke:
        n_req, n_slots, plen, gen, chunk, rate = 8, 2, 8, 8, 4, 1.0
    else:
        n_req, n_slots, plen, gen, chunk, rate = 16, 4, 16, 16, 4, 1.2
    block = 4
    max_len = plen + gen - 1 + chunk
    cfg = dataclasses.replace(
        configs.get_config(ARCH).reduced(compute_dtype="float32"),
        n_heads=heads, n_kv_heads=heads)
    params = get_family(cfg).init_params(jax.random.PRNGKey(0), cfg)
    trace = poisson_trace(np.random.default_rng(11), n_req, rate,
                          cfg.vocab, plen, gen)

    def _pass(mesh):
        eng = Engine(cfg, params, max_len=max_len, seed=0, paged=True,
                     block_size=block, sanitize=sanitize, mesh=mesh)
        sched = Scheduler(eng, n_slots=n_slots, chunk_size=chunk,
                          chunked_prefill=True)
        t0 = time.perf_counter()
        done, _ = drive_trace(sched, trace)
        return done, sched, time.perf_counter() - t0

    done_1, _, base_wall = _pass(None)
    from repro.launch.mesh import make_host_mesh
    done_s, sched_s, s_wall = _pass(make_host_mesh(mp))

    assert done_1.keys() == done_s.keys()
    for rid in done_1:
        assert (done_s[rid].tokens == done_1[rid].tokens).all(), \
            f"sharded serving changed the tokens of request {rid}"
        assert done_s[rid].finished_step == done_1[rid].finished_step, \
            f"sharded serving changed the schedule of request {rid}"
    if sanitize:
        assert sched_s.n_leaked == 0 and not sched_s.leak_report(), \
            f"sanitizer found leaked arena blocks: {sched_s.leak_report()}"

    spec = sched_s.cache["k"].sharding.spec
    assert "model" in spec, \
        f"arena k is not head-sharded over 'model': spec={spec}"
    rep = cache_report(sched_s.cache)
    content = sum(int(np.prod(sched_s.cache[k].shape)) *
                  sched_s.cache[k].dtype.itemsize for k in ("k", "v"))
    meta = rep["bytes"] - content
    per_dev_content = rep["per_device_bytes"] - meta
    n_blocks = sched_s.cache["k"].shape[1]
    one_block = content // n_blocks
    assert per_dev_content <= content // mp + one_block, (
        f"per-device arena content {per_dev_content} B exceeds "
        f"content/mp + one block ({content // mp} + {one_block} B) "
        f"at model_parallel={mp}")
    stats = sched_s.stats
    return [
        (f"serve_sharded_mp{mp}_b{n_slots}_n{n_req}_c{chunk}",
         s_wall * 1e6,
         f"tokens_match_single_device=1.0 model_parallel={mp} "
         f"n_devices={n_dev} "
         f"per_device_kv_bytes={rep['per_device_bytes']} "
         f"total_kv_bytes={rep['bytes']} "
         f"per_device_content_bytes={per_dev_content} "
         f"content_bytes={content} "
         f"content_shard_frac={per_dev_content / content:.3f} "
         f"step_wall_p50_ms={stats['step_wall_p50_ms']:.1f} "
         f"step_wall_p99_ms={stats['step_wall_p99_ms']:.1f} "
         f"wall_vs_single={s_wall / max(base_wall, 1e-9):.2f}x"),
    ]


if __name__ == "__main__":
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    sanitize = "--sanitize" in argv
    print("name,us_per_call,derived")
    sections = [f for f in ("--paged", "--prefix-share", "--chunked",
                            "--sharded")
                if f in argv]
    if sections:                       # run ONLY the named sections
        rows = []
        if "--paged" in argv:
            rows += run_paged_comparison(smoke=smoke, sanitize=sanitize)
        if "--prefix-share" in argv:
            rows += run_prefix_comparison(smoke=smoke, sanitize=sanitize)
        if "--chunked" in argv:
            rows += run_chunked_comparison(smoke=smoke, sanitize=sanitize)
        if "--sharded" in argv:
            rows += run_sharded_comparison(smoke=smoke, sanitize=sanitize)
    else:
        rows = run(smoke=smoke, paged=not smoke)
        if smoke:
            rows += run_prefix_comparison(smoke=smoke, sanitize=sanitize)
    for row in rows:
        print(",".join(str(x) for x in row))
