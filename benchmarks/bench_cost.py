"""Paper Table IV analogue: per-op hardware cost.

LUT counts do not exist on TPU; the cost metrics that do are HLO FLOPs,
bytes accessed, and wall time per element (CPU interpret — directional
only).  Reported per PVU op and for the three Pallas kernels.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import posit as P
from repro.core.types import POSIT16, POSIT32
from repro.kernels import ops


def _cost(fn, *args):
    jitted = jax.jit(fn)
    c = jitted.lower(*args).compile().cost_analysis()
    t0 = time.perf_counter()
    jax.block_until_ready(jitted(*args))
    t1 = time.perf_counter()
    jax.block_until_ready(jitted(*args))
    dt = (time.perf_counter() - t1) * 1e6
    return c.get("flops", 0.0), c.get("bytes accessed", 0.0), dt


def run(n: int = 1 << 16, seed: int = 0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 2 ** 32, n, dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2 ** 32, n, dtype=np.uint32))
    rows = []
    for name, fn in [
        ("pvu_add", lambda x, y: P.vpadd(x, y, POSIT32)),
        ("pvu_mul", lambda x, y: P.vpmul(x, y, POSIT32)),
        ("pvu_div_nr3", lambda x, y: P.vpdiv(x, y, POSIT32, mode="nr3")),
        ("pvu_div_exact",
         lambda x, y: P.vpdiv(x, y, POSIT32, mode="exact")),
    ]:
        fl, by, dt = _cost(fn, a, b)
        rows.append((name, dt, f"flops={fl:.3g} bytes={by:.3g} "
                     f"ns_per_elt={dt * 1e3 / n:.1f}"))

    a2 = a.reshape(256, -1)
    b2 = b.reshape(256, -1)
    fl, by, dt = _cost(lambda x, y: P.vpdot(x, y, POSIT32), a2, b2)
    rows.append(("pvu_dot", dt, f"flops={fl:.3g} bytes={by:.3g}"))

    x = jnp.asarray(rng.standard_normal((512, 512)).astype(np.float32))
    fl, by, dt = _cost(lambda t: ops.quantize(t, POSIT16), x)
    rows.append(("kernel_codec_quant", dt, f"flops={fl:.3g} bytes={by:.3g}"))
    w = ops.quantize(x, POSIT16)
    fl, by, dt = _cost(lambda t, ww: ops.gemm(t, ww, POSIT16), x, w)
    rows.append(("kernel_posit_gemm", dt, f"flops={fl:.3g} bytes={by:.3g}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
